//! End-to-end MD integration: coordinator + neighbor lists + integrator +
//! SNAP engines, run as a physical simulation.

use repro::coordinator::{ForceField, SimConfig, Simulation};
use repro::md::lattice;
use repro::snap::coeff::SnapCoeffs;
use repro::snap::variants::Variant;
use repro::snap::{SnapIndex, SnapParams};
use repro::util::XorShift;
use std::sync::Arc;

fn build_sim(variant: Variant, twojmax: usize, cells: usize, t0: f64) -> Simulation {
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    let mut s = lattice::bcc(cells, cells, cells, lattice::BCC_W_LATTICE, 183.84);
    let mut rng = XorShift::new(99);
    if t0 > 0.0 {
        s.seed_velocities(t0, &mut rng);
    }
    let engine = variant.build(params, idx, coeffs.beta);
    let field = ForceField::new(engine, 32, 32);
    Simulation::new(
        s,
        field,
        params.rcut(),
        SimConfig {
            dt: 0.0002,
            neighbor_every: 5,
            skin: 0.3,
            thermo_every: 0,
            langevin: None,
        },
    )
}

#[test]
fn nve_conserves_energy_with_fused_engine() {
    let mut sim = build_sim(Variant::Fused, 2, 3, 60.0);
    let stats = sim.run(80, &mut std::io::sink()).unwrap();
    assert!(
        stats.energy_drift_per_atom < 1e-5,
        "NVE drift {} eV/atom",
        stats.energy_drift_per_atom
    );
}

/// Multi-element NVE: the B2 W–Be alloy with a synthetic 2-element
/// potential conserves energy end to end — per-pair cutoffs, density
/// weights, per-element beta blocks AND per-atom masses in the integrator
/// must all be mutually consistent for this to hold.
#[test]
fn nve_conserves_energy_on_the_wbe_alloy() {
    let twojmax = 2usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = SnapCoeffs::synthetic_multi(twojmax, idx.idxb_max, 2, 42);
    let mut s = lattice::wbe_alloy(3);
    let mut rng = XorShift::new(99);
    s.seed_velocities(60.0, &mut rng);
    let engine = Variant::Fused.build_multi(
        params,
        idx,
        coeffs.beta.clone(),
        coeffs.elements.clone(),
    );
    let cutoff = coeffs.elements.max_cutoff(params.rcutfac).max(params.rcut());
    let mut sim = Simulation::new(
        s,
        ForceField::new(engine, 32, 32),
        cutoff,
        SimConfig {
            // light Be atoms need a shorter step for the same Verlet error
            dt: 0.0001,
            neighbor_every: 5,
            skin: 0.3,
            thermo_every: 0,
            langevin: None,
        },
    );
    let stats = sim.run(80, &mut std::io::sink()).unwrap();
    assert!(
        stats.energy_drift_per_atom < 1e-5,
        "alloy NVE drift {} eV/atom",
        stats.energy_drift_per_atom
    );
    assert!(stats.thermo.iter().all(|t| t.e_total.is_finite()));
}

#[test]
fn nve_trajectories_agree_across_engines() {
    // the same initial conditions must give the same trajectory regardless
    // of which engine computes forces
    let run = |v: Variant| {
        let mut sim = build_sim(v, 2, 3, 40.0);
        sim.run(25, &mut std::io::sink()).unwrap();
        sim.structure.pos.clone()
    };
    let a = run(Variant::V0Baseline);
    let b = run(Variant::Fused);
    let c = run(Variant::V7);
    for (i, ((x, y), z)) in a.iter().zip(b.iter()).zip(c.iter()).enumerate() {
        assert!((x - y).abs() < 1e-7, "pos[{i}] baseline vs fused: {x} vs {y}");
        assert!((x - z).abs() < 1e-7, "pos[{i}] baseline vs V7");
    }
}

#[test]
fn neighbor_rebuild_policy_does_not_change_physics() {
    let run = |every: usize| {
        let mut sim = build_sim(Variant::Fused, 2, 3, 40.0);
        sim.cfg.neighbor_every = every;
        sim.run(20, &mut std::io::sink()).unwrap();
        // positions are wrapped at rebuild time, so raw coordinates differ
        // by exact box lengths between cadences; compare wrapped coords
        sim.structure.wrap_all();
        sim.structure.pos.clone()
    };
    // the skin is generous enough that rebuild cadence is invisible over
    // this horizon
    let a = run(1);
    let b = run(10);
    // wrapping at different times perturbs rij at the ulp level (different
    // fp rounding of x vs x+L), and MD amplifies it; equality is physical,
    // not bitwise
    for (x, y) in a.iter().zip(b.iter()) {
        assert!((x - y).abs() < 1e-6, "{x} vs {y}");
    }
}

#[test]
fn langevin_equilibrates_toward_target() {
    let mut sim = build_sim(Variant::Fused, 2, 3, 0.0);
    sim.cfg.langevin = Some((150.0, 0.05, 3));
    let stats = sim.run(150, &mut std::io::sink()).unwrap();
    let tail: Vec<f64> = stats.thermo.iter().rev().take(4).map(|t| t.temp).collect();
    let t_mean = tail.iter().sum::<f64>() / tail.len() as f64;
    assert!(
        t_mean > 40.0 && t_mean < 400.0,
        "Langevin pulled T to {t_mean}, target 150"
    );
}

#[test]
fn stage_times_are_recorded() {
    let mut sim = build_sim(Variant::Fused, 2, 3, 10.0);
    sim.run(3, &mut std::io::sink()).unwrap();
    let report = sim.field.times.report();
    assert!(report.contains("execute"), "{report}");
    assert!(report.contains("pack"));
    assert!(report.contains("scatter"));
    assert!(sim.field.times.get("execute") > sim.field.times.get("pack"));
}

#[test]
fn virial_pressure_is_finite_and_symmetric_lattice_is_isotropic() {
    let mut sim = build_sim(Variant::Fused, 2, 3, 0.0);
    let r = sim.compute_forces().unwrap().clone();
    // perfect cubic lattice: diagonal virial components equal, off-diagonal ~0
    let w = r.virial;
    assert!((w[0] - w[4]).abs() < 1e-6 * (1.0 + w[0].abs()));
    assert!((w[0] - w[8]).abs() < 1e-6 * (1.0 + w[0].abs()));
    for (i, v) in w.iter().enumerate() {
        if i % 4 != 0 {
            assert!(v.abs() < 1e-8, "off-diagonal virial {i}: {v}");
        }
    }
}

#[test]
fn nve_error_scales_as_dt_squared() {
    // symplectic integrator + consistent forces => halving dt quarters the
    // energy error; a force/energy inconsistency would scale ~dt^1
    let drift = |dt: f64| {
        let mut sim = build_sim(Variant::Fused, 2, 3, 60.0);
        sim.cfg.dt = dt;
        // fixed physical time horizon
        let steps = (0.016 / dt).round() as usize;
        sim.run(steps, &mut std::io::sink()).unwrap().energy_drift_per_atom
    };
    let d1 = drift(0.0004);
    let d2 = drift(0.0002);
    let ratio = d1 / d2.max(1e-15);
    assert!(
        ratio > 2.0,
        "energy error ratio dt->dt/2 is {ratio:.2} (want ~4, i.e. > 2): d1={d1:.3e} d2={d2:.3e}"
    );
}
