//! The `compute_into` dispatch contract, end to end through the public
//! API: caller-owned output buffers are reused (zero allocations after
//! warmup), the `compute` shim is bitwise-identical to `compute_into` for
//! every native variant and the sharded wrapper, and shape violations come
//! back as typed errors instead of panics.

use repro::config::EngineSpec;
use repro::snap::coeff::SnapCoeffs;
use repro::snap::engine::{EngineError, TileInput, TileOutput};
use repro::snap::variants::Variant;
use repro::snap::SnapIndex;
use repro::util::XorShift;

fn random_tile(seed: u64, na: usize, nn: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rng = XorShift::new(seed);
    let mut rij = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..na * nn {
        for _ in 0..3 {
            rij.push(rng.uniform(-2.4, 2.4));
        }
        mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
    }
    (rij, mask)
}

fn beta_for(twojmax: usize) -> Vec<f64> {
    SnapCoeffs::synthetic(twojmax, SnapIndex::new(twojmax).idxb_max, 42).beta
}

/// Repeated `compute_into` calls on one engine never grow the output
/// buffers after warmup: the steady-state serving/MD contract of zero
/// per-dispatch output allocations.
#[test]
fn repeated_compute_into_does_not_grow_output_capacity() {
    for (label, shards) in [("serial", 1usize), ("sharded", 3)] {
        let mut engine = EngineSpec::new(2)
            .engine("fused")
            .beta(beta_for(2))
            .shards(shards)
            .min_atoms_per_shard(1)
            .build()
            .unwrap();
        let (na, nn) = (9usize, 4usize);
        let (rij, mask) = random_tile(7, na, nn);
        let big = TileInput { num_atoms: na, num_nbor: nn, rij: &rij, mask: &mask, elems: None };
        let (rij_s, mask_s) = random_tile(8, 2, nn);
        let small =
            TileInput { num_atoms: 2, num_nbor: nn, rij: &rij_s, mask: &mask_s, elems: None };

        let mut out = TileOutput::default();
        engine.compute_into(&big, &mut out).unwrap(); // warmup: sizes the buffers
        let (cap_ei, cap_dedr) = (out.ei.capacity(), out.dedr.capacity());
        let (ptr_ei, ptr_dedr) = (out.ei.as_ptr(), out.dedr.as_ptr());
        for rep in 0..20 {
            // alternate shapes <= the warmup tile: reuse, never regrow
            let tile = if rep % 3 == 2 { &small } else { &big };
            engine.compute_into(tile, &mut out).unwrap();
            assert_eq!(out.ei.len(), tile.num_atoms);
            assert_eq!(out.dedr.len(), tile.num_atoms * nn * 3);
            assert_eq!(out.ei.capacity(), cap_ei, "{label}: ei capacity grew at rep {rep}");
            assert_eq!(
                out.dedr.capacity(),
                cap_dedr,
                "{label}: dedr capacity grew at rep {rep}"
            );
            assert_eq!(out.ei.as_ptr(), ptr_ei, "{label}: ei reallocated at rep {rep}");
            assert_eq!(out.dedr.as_ptr(), ptr_dedr, "{label}: dedr reallocated at rep {rep}");
        }
    }
}

/// `compute` (the allocating shim) and `compute_into` must agree bitwise
/// for every native variant of the ladder ∪ fig1 set and for the sharded
/// wrapper — the default method is a pure convenience, never a second
/// implementation.
#[test]
fn compute_shim_is_bitwise_identical_to_compute_into_ladder_wide() {
    let twojmax = 2usize;
    let beta = beta_for(twojmax);
    let (na, nn) = (5usize, 4usize);
    let (rij, mask) = random_tile(31, na, nn);
    let tile = TileInput { num_atoms: na, num_nbor: nn, rij: &rij, mask: &mask, elems: None };
    for v in Variant::ladder().iter().chain(Variant::fig1()) {
        let mut engine = EngineSpec::new(twojmax)
            .variant(*v)
            .beta(beta.clone())
            .build()
            .unwrap();
        let shimmed = engine.compute(&tile);
        let mut into = TileOutput::default();
        engine.compute_into(&tile, &mut into).unwrap();
        assert_eq!(shimmed.ei, into.ei, "{v:?}: ei diverges");
        assert_eq!(shimmed.dedr, into.dedr, "{v:?}: dedr diverges");
    }
    // the sharded wrapper honors the same equivalence
    let mut sharded = EngineSpec::new(twojmax)
        .engine("fused")
        .beta(beta)
        .shards(3)
        .min_atoms_per_shard(1)
        .build()
        .unwrap();
    let shimmed = sharded.compute(&tile);
    let mut into = TileOutput::default();
    sharded.compute_into(&tile, &mut into).unwrap();
    assert_eq!(shimmed.ei, into.ei, "sharded: ei diverges");
    assert_eq!(shimmed.dedr, into.dedr, "sharded: dedr diverges");
}

/// Shape violations are typed `BadShape` errors from `compute_into` — for
/// the native engines and through the sharded wrapper — and the engine
/// stays usable afterwards.
#[test]
fn bad_shapes_are_typed_errors_not_panics() {
    for shards in [1usize, 3] {
        let mut engine = EngineSpec::new(2)
            .engine("fused")
            .beta(beta_for(2))
            .shards(shards)
            .build()
            .unwrap();
        let (rij, mask) = random_tile(3, 2, 3);
        let mut out = TileOutput::default();
        // rij too short for the claimed shape
        let bad = TileInput { num_atoms: 2, num_nbor: 4, rij: &rij, mask: &mask, elems: None };
        let err = engine.compute_into(&bad, &mut out).unwrap_err();
        assert!(matches!(err, EngineError::BadShape(_)), "shards={shards}: {err:?}");
        // a well-shaped tile still computes on the same engine + buffer
        let good = TileInput { num_atoms: 2, num_nbor: 3, rij: &rij, mask: &mask, elems: None };
        engine.compute_into(&good, &mut out).unwrap();
        assert_eq!(out.ei.len(), 2);
        assert!(out.ei.iter().all(|e| e.is_finite()));
    }
}
