//! Dual-protocol serving tests: one server, one port, two wire formats.
//! The binary `repro-frame-v1` path must be *bit-identical* to the JSON
//! path (the wire changes encoding cost, never physics), malformed binary
//! frames must come back as structured errors without hurting anyone else,
//! and admission control must shed — not stall — under queue pressure.

use repro::config::EngineSpec;
use repro::coordinator::server::{serve_with_stats, shutdown, ServeOptions, ServerStats};
use repro::coordinator::wire::{self, ErrorCode, Frame};
use repro::snap::coeff::SnapCoeffs;
use repro::snap::{EngineFactory, SnapIndex};
use repro::util::json::Json;
use repro::util::XorShift;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

fn factory(engine: &str, twojmax: usize) -> EngineFactory {
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    EngineSpec::new(twojmax)
        .engine(engine)
        .beta(coeffs.beta)
        .build_factory()
        .unwrap()
        .factory
}

fn multi_factory(twojmax: usize) -> EngineFactory {
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic_multi(twojmax, idx.idxb_max, 2, 42);
    EngineSpec::new(twojmax)
        .engine("fused")
        .beta(coeffs.beta)
        .elements(coeffs.elements.clone())
        .build_factory()
        .unwrap()
        .factory
}

struct TestServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    handle: std::thread::JoinHandle<std::io::Result<()>>,
}

impl TestServer {
    fn start_with_factory(opts: ServeOptions, f: EngineFactory) -> Self {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let (stop2, stats2) = (stop.clone(), stats.clone());
        let handle = std::thread::spawn(move || {
            serve_with_stats(listener, f, &opts, stop2, stats2)
        });
        TestServer { addr, stop, stats, handle }
    }

    fn start(opts: ServeOptions, engine: &str, twojmax: usize) -> Self {
        Self::start_with_factory(opts, factory(engine, twojmax))
    }

    fn finish(self) {
        shutdown(self.addr, &self.stop);
        self.handle.join().unwrap().unwrap();
    }
}

fn sequential_opts() -> ServeOptions {
    ServeOptions {
        workers: 1,
        batch_window: std::time::Duration::ZERO,
        queue_depth: 64,
        max_batch_atoms: 32,
        ..ServeOptions::default()
    }
}

/// A line-delimited JSON client.
struct JsonClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl JsonClient {
    fn connect(addr: SocketAddr) -> JsonClient {
        let conn = TcpStream::connect(addr).unwrap();
        let writer = conn.try_clone().unwrap();
        JsonClient { writer, reader: BufReader::new(conn) }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    }
}

/// A repro-frame-v1 client (performs the hello handshake on connect).
struct BinClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl BinClient {
    fn connect(addr: SocketAddr) -> BinClient {
        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        writer.write_all(&wire::encode_hello(wire::VERSION)).unwrap();
        let mut ack = [0u8; 2];
        reader.read_exact(&mut ack).unwrap();
        assert_eq!(ack, wire::encode_hello_ack(), "bad hello ack");
        BinClient { writer, reader }
    }

    fn send(&mut self, frame: &[u8]) {
        self.writer.write_all(frame).unwrap();
    }

    fn recv(&mut self) -> Frame {
        wire::read_frame(&mut self.reader)
            .expect("frame read")
            .expect("reply frames are well-formed")
    }
}

/// Deterministic tile with `na` atoms, `nn` neighbor slots, some masked —
/// the same geometry generator as the JSON-side concurrency tests.
fn tile_data(seed: u64, na: usize, nn: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rng = XorShift::new(seed);
    let mut rij = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..na * nn {
        loop {
            let v = [
                rng.uniform(-2.4, 2.4),
                rng.uniform(-2.4, 2.4),
                rng.uniform(-2.4, 2.4),
            ];
            if (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt() > 0.5 {
                rij.extend_from_slice(&v);
                break;
            }
        }
        mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
    }
    (rij, mask)
}

/// The JSON request for the same tile (`x.to_string()` round-trips f64
/// exactly, so both wires submit bit-identical inputs).
fn json_request(na: usize, nn: usize, rij: &[f64], mask: &[f64]) -> String {
    let fmt = |v: &[f64]| {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
    };
    format!(
        "{{\"num_atoms\": {na}, \"num_nbor\": {nn}, \"rij\": [{}], \"mask\": [{}]}}",
        fmt(rij),
        fmt(mask)
    )
}

/// Extract (ei, dedr) from a JSON ok-reply (the `{:.17e}` formatting
/// round-trips f64 exactly, so these are the server's exact output bits).
fn parse_json_ok(reply: &str) -> (Vec<f64>, Vec<f64>) {
    let j = Json::parse(reply).unwrap_or_else(|e| panic!("bad reply ({e}): {reply}"));
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{reply}");
    let ei = j.get("ei").and_then(Json::as_f64_vec).expect("ei array");
    let dedr = j.get("dedr").and_then(Json::as_f64_vec).expect("dedr array");
    (ei, dedr)
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}[{i}]: {x:?} != {y:?} (bitwise)"
        );
    }
}

/// Core differential: the same tile over JSON and over repro-frame-v1 must
/// produce bit-identical outputs — the binary wire changes serialization
/// cost, never the physics.
#[test]
fn binary_replies_are_bit_identical_to_json() {
    let srv = TestServer::start(sequential_opts(), "fused", 2);

    for (seed, na, nn) in [(31u64, 1usize, 4usize), (32, 3, 4), (33, 12, 6)] {
        let (rij, mask) = tile_data(seed, na, nn);

        let mut jc = JsonClient::connect(srv.addr);
        let (json_ei, json_dedr) = parse_json_ok(&jc.roundtrip(&json_request(na, nn, &rij, &mask)));
        drop(jc);

        let mut bc = BinClient::connect(srv.addr);
        bc.send(&wire::encode_compute(na, nn, &rij, &mask, None));
        match bc.recv() {
            Frame::Result { num_atoms, num_nbor, ei, dedr } => {
                assert_eq!((num_atoms, num_nbor), (na, nn));
                assert_bits_eq(&json_ei, &ei, "ei");
                assert_bits_eq(&json_dedr, &dedr, "dedr");
            }
            other => panic!("expected result, got {other:?}"),
        }
    }
    srv.finish();
}

/// Same differential through the typed `ielems`/`jelems` channel on a
/// multi-element server.
#[test]
fn typed_binary_replies_are_bit_identical_to_json() {
    let srv = TestServer::start_with_factory(sequential_opts(), multi_factory(2));
    let (na, nn) = (3usize, 4usize);
    let (rij, mask) = tile_data(77, na, nn);
    let ielems: Vec<i32> = (0..na).map(|a| (a % 2) as i32).collect();
    let jelems: Vec<i32> = (0..na * nn).map(|r| (r % 2) as i32).collect();

    let fmt_i = |v: &[i32]| {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
    };
    let typed_json = format!(
        "{}, \"ielems\": [{}], \"jelems\": [{}]}}",
        json_request(na, nn, &rij, &mask).trim_end_matches('}'),
        fmt_i(&ielems),
        fmt_i(&jelems)
    );
    let mut jc = JsonClient::connect(srv.addr);
    let (json_ei, json_dedr) = parse_json_ok(&jc.roundtrip(&typed_json));
    drop(jc);

    let mut bc = BinClient::connect(srv.addr);
    bc.send(&wire::encode_compute(na, nn, &rij, &mask, Some((&ielems, &jelems))));
    match bc.recv() {
        Frame::Result { ei, dedr, .. } => {
            assert_bits_eq(&json_ei, &ei, "typed ei");
            assert_bits_eq(&json_dedr, &dedr, "typed dedr");
        }
        other => panic!("expected result, got {other:?}"),
    }
    srv.finish();
}

/// Mixed-protocol serving: JSON and binary clients hammer one server
/// concurrently (coalescer and worker pool on); every reply must match the
/// sequential ground truth bit for bit, regardless of which wire carried it.
#[test]
fn mixed_protocol_clients_share_one_server_bitwise() {
    let tiles: Vec<(usize, usize, Vec<f64>, Vec<f64>)> = (0..6u64)
        .map(|k| {
            let (na, nn) = if k % 3 == 2 { (3, 4) } else { (1, 4) };
            let (rij, mask) = tile_data(400 + k, na, nn);
            (na, nn, rij, mask)
        })
        .collect();

    // sequential ground truth, via JSON (exact round-trip)
    let seq = TestServer::start(sequential_opts(), "fused", 2);
    let mut jc = JsonClient::connect(seq.addr);
    let expected: Vec<(Vec<f64>, Vec<f64>)> = tiles
        .iter()
        .map(|(na, nn, rij, mask)| {
            parse_json_ok(&jc.roundtrip(&json_request(*na, *nn, rij, mask)))
        })
        .collect();
    drop(jc);
    seq.finish();

    let opts = ServeOptions {
        workers: 4,
        batch_window: std::time::Duration::from_micros(300),
        queue_depth: 64,
        max_batch_atoms: 32,
        ..ServeOptions::default()
    };
    let srv = TestServer::start(opts, "fused", 2);
    let addr = srv.addr;
    let tiles = Arc::new(tiles);
    let expected = Arc::new(expected);
    let barrier = Arc::new(Barrier::new(8));
    let handles: Vec<_> = (0..8)
        .map(|c| {
            let (tiles, expected, barrier) = (tiles.clone(), expected.clone(), barrier.clone());
            std::thread::spawn(move || {
                if c % 2 == 0 {
                    let mut client = JsonClient::connect(addr);
                    barrier.wait();
                    for rep in 0..3 {
                        for (k, (na, nn, rij, mask)) in tiles.iter().enumerate() {
                            let req = json_request(*na, *nn, rij, mask);
                            let got = parse_json_ok(&client.roundtrip(&req));
                            assert_bits_eq(
                                &expected[k].0,
                                &got.0,
                                &format!("json client {c} rep {rep} tile {k} ei"),
                            );
                            assert_bits_eq(
                                &expected[k].1,
                                &got.1,
                                &format!("json client {c} rep {rep} tile {k} dedr"),
                            );
                        }
                    }
                } else {
                    let mut client = BinClient::connect(addr);
                    barrier.wait();
                    for rep in 0..3 {
                        for (k, (na, nn, rij, mask)) in tiles.iter().enumerate() {
                            client.send(&wire::encode_compute(*na, *nn, rij, mask, None));
                            match client.recv() {
                                Frame::Result { ei, dedr, .. } => {
                                    assert_bits_eq(
                                        &expected[k].0,
                                        &ei,
                                        &format!("bin client {c} rep {rep} tile {k} ei"),
                                    );
                                    assert_bits_eq(
                                        &expected[k].1,
                                        &dedr,
                                        &format!("bin client {c} rep {rep} tile {k} dedr"),
                                    );
                                }
                                other => panic!("client {c}: expected result, got {other:?}"),
                            }
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    srv.finish();
}

fn raw_frame(cmd: u8, body: &[u8]) -> Vec<u8> {
    let mut f = Vec::new();
    f.extend_from_slice(&((1 + body.len()) as u32).to_le_bytes());
    f.push(cmd);
    f.extend_from_slice(body);
    f
}

/// Well-framed but invalid binary frames get structured error replies and
/// the connection (and worker) survive to serve the next request.
#[test]
fn malformed_binary_frames_are_structured_and_survivable() {
    let srv = TestServer::start(sequential_opts(), "fused", 2);
    let mut client = BinClient::connect(srv.addr);

    // unknown command tag
    client.send(&raw_frame(0x55, &[]));
    match client.recv() {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::UnknownCmd, "{message}");
            assert!(message.contains("0x55"), "{message}");
        }
        other => panic!("expected error, got {other:?}"),
    }

    // compute body length that disagrees with its own header
    let mut body = Vec::new();
    body.extend_from_slice(&2u32.to_le_bytes()); // num_atoms
    body.extend_from_slice(&2u32.to_le_bytes()); // num_nbor
    body.push(0); // untyped
    body.extend_from_slice(&1.5f64.to_le_bytes()); // far too few floats
    client.send(&raw_frame(wire::CMD_COMPUTE, &body));
    match client.recv() {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::BadFrame, "{message}");
            assert!(message.contains("length mismatch"), "{message}");
        }
        other => panic!("expected error, got {other:?}"),
    }

    // bad typed flag
    let mut body = Vec::new();
    body.extend_from_slice(&1u32.to_le_bytes());
    body.extend_from_slice(&1u32.to_le_bytes());
    body.push(7);
    client.send(&raw_frame(wire::CMD_COMPUTE, &body));
    match client.recv() {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::BadFrame, "{message}");
            assert!(message.contains("typed flag"), "{message}");
        }
        other => panic!("expected error, got {other:?}"),
    }

    // zero-length frame
    client.send(&0u32.to_le_bytes());
    match client.recv() {
        Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadFrame),
        other => panic!("expected error, got {other:?}"),
    }

    // the same connection and the single worker still compute correctly
    let (rij, mask) = tile_data(91, 1, 4);
    client.send(&wire::encode_compute(1, 4, &rij, &mask, None));
    match client.recv() {
        Frame::Result { num_atoms, ei, .. } => {
            assert_eq!(num_atoms, 1);
            assert!(ei[0].is_finite());
        }
        other => panic!("connection/worker died after bad frames: {other:?}"),
    }

    drop(client);
    srv.finish();
}

/// Frames whose declared length is untrustworthy (oversize) poison the
/// framing itself: the server replies once, then closes that connection —
/// but other connections and the workers are untouched.
#[test]
fn oversize_frame_closes_connection_but_not_server() {
    let srv = TestServer::start(sequential_opts(), "fused", 2);

    let mut bad = BinClient::connect(srv.addr);
    let huge = (wire::MAX_FRAME_LEN as u32) + 1;
    bad.writer.write_all(&huge.to_le_bytes()).unwrap();
    bad.writer.flush().unwrap();
    match bad.recv() {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::BadFrame, "{message}");
            assert!(message.contains("exceeds"), "{message}");
        }
        other => panic!("expected error, got {other:?}"),
    }
    // ... and then EOF: the connection is gone
    let mut rest = Vec::new();
    bad.reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "no bytes after the fatal error");

    // a fresh connection is served normally
    let mut good = BinClient::connect(srv.addr);
    let (rij, mask) = tile_data(92, 1, 4);
    good.send(&wire::encode_compute(1, 4, &rij, &mask, None));
    assert!(matches!(good.recv(), Frame::Result { .. }));
    drop(good);
    srv.finish();
}

/// A hello with an unsupported version is refused with a structured error
/// and a close; the server keeps serving v1 clients.
#[test]
fn unsupported_hello_version_is_refused() {
    let srv = TestServer::start(sequential_opts(), "fused", 2);

    let conn = TcpStream::connect(srv.addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    writer.write_all(&wire::encode_hello(9)).unwrap();
    match wire::read_frame(&mut reader).unwrap().unwrap() {
        Frame::Error { code, message } => {
            assert_eq!(code, ErrorCode::BadFrame, "{message}");
            assert!(message.contains("version 9"), "{message}");
        }
        other => panic!("expected error, got {other:?}"),
    }
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "connection must close after a refused hello");

    // v1 still negotiates fine
    let _ok = BinClient::connect(srv.addr);
    srv.finish();
}

/// Admission control: with a tiny ingress queue and a slow engine, a burst
/// must be shed with structured `overloaded` replies — never a stalled
/// event loop — and the accounting must still close exactly.
#[test]
fn overload_sheds_with_structured_replies_and_exact_accounting() {
    use repro::snap::engine::{EngineError, ForceEngine, TileInput, TileOutput};

    /// Engine that takes 100ms per dispatch, so a burst outruns the pipeline.
    struct Slow;
    impl ForceEngine for Slow {
        fn name(&self) -> &str {
            "slow"
        }
        fn compute_into(
            &mut self,
            input: &TileInput,
            out: &mut TileOutput,
        ) -> Result<(), EngineError> {
            input.check()?;
            std::thread::sleep(std::time::Duration::from_millis(100));
            out.reset(input.num_atoms, input.num_nbor);
            out.ei.fill(2.0);
            Ok(())
        }
        fn footprint(&self, _na: usize, _nn: usize) -> repro::snap::memory::MemoryFootprint {
            repro::snap::memory::MemoryFootprint::new()
        }
    }

    let f: EngineFactory = Arc::new(|| Ok(Box::new(Slow) as Box<dyn ForceEngine>));
    let opts = ServeOptions {
        workers: 1,
        batch_window: std::time::Duration::ZERO,
        queue_depth: 1,
        max_batch_atoms: 32,
        ..ServeOptions::default()
    };
    let srv = TestServer::start_with_factory(opts, f);

    let mut client = BinClient::connect(srv.addr);
    let (rij, mask) = tile_data(55, 1, 4);
    let burst = 12usize;
    let frame = wire::encode_compute(1, 4, &rij, &mask, None);
    let mut wave: Vec<u8> = Vec::new();
    for _ in 0..burst {
        wave.extend_from_slice(&frame);
    }
    client.send(&wave); // one write: the whole burst lands at once

    let (mut ok, mut shed) = (0u64, 0u64);
    for _ in 0..burst {
        match client.recv() {
            Frame::Result { .. } => ok += 1,
            Frame::Error { code: ErrorCode::Overloaded, message } => {
                assert!(message.contains("overloaded"), "{message}");
                shed += 1;
            }
            other => panic!("unexpected reply under pressure: {other:?}"),
        }
    }
    assert_eq!(ok + shed, burst as u64);
    assert!(ok >= 1, "the first request always fits the queue");
    assert!(
        shed >= 1,
        "a 12-deep burst into a depth-1 queue with a 100ms engine must shed"
    );

    // accounting closes exactly: total = ok + err + stats, shed subset of err
    client.send(&wire::encode_stats_request());
    let doc = match client.recv() {
        Frame::StatsJson(doc) => doc,
        other => panic!("expected stats, got {other:?}"),
    };
    let j = Json::parse(&doc).expect("stats doc parses");
    let s = j.get("stats").expect("stats object");
    let get = |k: &str| s.get(k).and_then(Json::as_usize).unwrap() as u64;
    assert_eq!(get("replies_ok"), ok, "{doc}");
    assert_eq!(get("replies_err"), shed, "{doc}");
    assert_eq!(get("requests_shed"), shed, "{doc}");
    assert_eq!(
        get("requests_total"),
        get("replies_ok") + get("replies_err") + get("stats_requests"),
        "accounting must close: {doc}"
    );
    // the caller-owned stats handle sees the same numbers as the wire
    assert_eq!(srv.stats.requests_shed.load(Ordering::Relaxed), shed);
    assert_eq!(srv.stats.replies_ok.load(Ordering::Relaxed), ok);
    drop(client);
    srv.finish();
}

/// The stats reply reports per-wire counters, per-session protocol state,
/// and per-stage latency histograms — the JSON→binary migration gauges.
#[test]
fn stats_report_wire_sessions_and_latency_histograms() {
    let srv = TestServer::start(sequential_opts(), "fused", 2);

    let mut jc = JsonClient::connect(srv.addr);
    let mut bc = BinClient::connect(srv.addr);
    let (rij, mask) = tile_data(66, 1, 4);
    let _ = parse_json_ok(&jc.roundtrip(&json_request(1, 4, &rij, &mask)));
    bc.send(&wire::encode_compute(1, 4, &rij, &mask, None));
    assert!(matches!(bc.recv(), Frame::Result { .. }));

    let reply = jc.roundtrip("{\"cmd\": \"stats\"}");
    let j = Json::parse(&reply).expect("stats reply parses");
    let s = j.get("stats").expect("stats object");

    let w = s.get("wire").expect("wire section");
    let get = |o: &Json, k: &str| o.get(k).and_then(Json::as_usize).unwrap();
    assert_eq!(get(w, "version"), wire::VERSION as usize, "{reply}");
    assert_eq!(get(w, "json_connections"), 1, "{reply}");
    assert_eq!(get(w, "binary_connections"), 1, "{reply}");
    assert_eq!(get(w, "json_requests"), 2, "{reply}"); // compute + stats
    assert_eq!(get(w, "binary_requests"), 1, "{reply}");
    let sessions = w.get("sessions").and_then(Json::as_arr).expect("sessions array");
    assert_eq!(sessions.len(), 2, "{reply}");
    let wires: Vec<&str> = sessions
        .iter()
        .filter_map(|e| e.get("wire").and_then(Json::as_str))
        .collect();
    assert!(wires.contains(&"json") && wires.contains(&"binary"), "{reply}");
    for e in sessions {
        assert!(e.get("requests").and_then(Json::as_usize).unwrap() >= 1, "{reply}");
    }

    let lat = s.get("latency").expect("latency section");
    for stage in ["parse", "queue_wait", "compute", "reply"] {
        let h = lat.get(stage).unwrap_or_else(|| panic!("latency.{stage} missing: {reply}"));
        assert!(
            h.get("count").and_then(Json::as_usize).unwrap() >= 2,
            "latency.{stage} undercounted: {reply}"
        );
        assert!(h.get("p50_us").and_then(Json::as_f64).is_some(), "{reply}");
        assert!(h.get("p99_us").and_then(Json::as_f64).is_some(), "{reply}");
    }

    drop(jc);
    drop(bc);
    srv.finish();
}
