//! Concurrent force-server tests: the serving pipeline (sessions -> bounded
//! queue -> coalescer -> worker pool) must be *invisible* to clients —
//! byte-identical replies to sequential serving, fault isolation between
//! connections, stats that add up, and a graceful shutdown path.

use repro::config::EngineSpec;
use repro::coordinator::server::{
    serve_with_stats, shutdown, ServeOptions, ServerStats,
};
use repro::snap::coeff::SnapCoeffs;
use repro::snap::{EngineFactory, SnapIndex};
use repro::util::json::Json;
use repro::util::XorShift;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Barrier};

fn factory(engine: &str, twojmax: usize) -> EngineFactory {
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    EngineSpec::new(twojmax)
        .engine(engine)
        .beta(coeffs.beta)
        .build_factory()
        .unwrap()
        .factory
}

struct TestServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    handle: std::thread::JoinHandle<std::io::Result<()>>,
}

impl TestServer {
    fn start(opts: ServeOptions, engine: &str, twojmax: usize) -> Self {
        Self::start_with_factory(opts, factory(engine, twojmax))
    }

    fn start_with_factory(opts: ServeOptions, f: EngineFactory) -> Self {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let (stop2, stats2) = (stop.clone(), stats.clone());
        let handle = std::thread::spawn(move || {
            serve_with_stats(listener, f, &opts, stop2, stats2)
        });
        TestServer { addr, stop, stats, handle }
    }

    fn finish(self) {
        shutdown(self.addr, &self.stop);
        self.handle.join().unwrap().unwrap();
    }
}

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let conn = TcpStream::connect(addr).unwrap();
        let writer = conn.try_clone().unwrap();
        Client { writer, reader: BufReader::new(conn) }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    }
}

/// Deterministic request with `na` atoms and `nn` neighbor slots (some
/// masked, exercising the padding contract through the wire protocol).
fn request_line(seed: u64, na: usize, nn: usize) -> String {
    let mut rng = XorShift::new(seed);
    let mut rij = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..na * nn {
        loop {
            let v = [
                rng.uniform(-2.4, 2.4),
                rng.uniform(-2.4, 2.4),
                rng.uniform(-2.4, 2.4),
            ];
            if (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt() > 0.5 {
                rij.extend_from_slice(&v);
                break;
            }
        }
        mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
    }
    let fmt = |v: &[f64]| {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
    };
    format!(
        "{{\"num_atoms\": {na}, \"num_nbor\": {nn}, \"rij\": [{}], \"mask\": [{}]}}",
        fmt(&rij),
        fmt(&mask)
    )
}

fn sequential_opts() -> ServeOptions {
    ServeOptions {
        workers: 1,
        batch_window: std::time::Duration::ZERO,
        queue_depth: 64,
        max_batch_atoms: 32,
        ..ServeOptions::default()
    }
}

fn concurrent_opts() -> ServeOptions {
    ServeOptions {
        workers: 4,
        batch_window: std::time::Duration::from_micros(300),
        queue_depth: 64,
        max_batch_atoms: 32,
        ..ServeOptions::default()
    }
}

#[test]
fn concurrent_serving_is_byte_identical_to_sequential() {
    // mergeable single-atom requests plus some multi-atom ones
    let requests: Vec<String> = (0..32)
        .map(|k| {
            if k % 4 == 3 {
                request_line(600 + k, 3, 4)
            } else {
                request_line(600 + k, 1, 4)
            }
        })
        .collect();

    // ground truth: one worker, no coalescing, one connection at a time
    let seq = TestServer::start(sequential_opts(), "fused", 2);
    let mut client = Client::connect(seq.addr);
    let expected: Vec<String> =
        requests.iter().map(|r| client.roundtrip(r)).collect();
    drop(client);
    seq.finish();
    for e in &expected {
        assert!(e.contains("\"ok\": true"), "sequential baseline failed: {e}");
    }

    // 8 concurrent clients, interleaved requests, workers + coalescer on
    let srv = TestServer::start(concurrent_opts(), "fused", 2);
    let barrier = Arc::new(Barrier::new(8));
    let requests = Arc::new(requests);
    let handles: Vec<_> = (0..8)
        .map(|c| {
            let addr = srv.addr;
            let barrier = barrier.clone();
            let requests = requests.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(addr);
                barrier.wait();
                // client c handles request indices c, c+8, c+16, ...
                let mut got = Vec::new();
                let mut k = c;
                while k < requests.len() {
                    got.push((k, client.roundtrip(&requests[k])));
                    k += 8;
                }
                got
            })
        })
        .collect();
    let mut replies = vec![String::new(); requests.len()];
    for h in handles {
        for (k, reply) in h.join().unwrap() {
            replies[k] = reply;
        }
    }
    for (k, (got, want)) in replies.iter().zip(expected.iter()).enumerate() {
        assert_eq!(got, want, "request {k}: concurrent reply diverges");
    }
    srv.finish();
}

#[test]
fn malformed_frames_do_not_disturb_other_connections() {
    let srv = TestServer::start(concurrent_opts(), "fused", 2);
    let addr = srv.addr;
    let barrier = Arc::new(Barrier::new(2));

    let b = barrier.clone();
    let chaos = std::thread::spawn(move || {
        let mut client = Client::connect(addr);
        b.wait();
        let bad = [
            "{oops",
            "{\"num_atoms\": 1}",
            "{\"num_atoms\": 1, \"num_nbor\": 2, \"rij\": [1], \"mask\": [1,1]}",
            "{\"cmd\": \"selfdestruct \\\"now\\\"\"}",
            "[1,2,3]",
        ];
        for (i, line) in bad.iter().cycle().take(20).enumerate() {
            let reply = client.roundtrip(line);
            let parsed = Json::parse(&reply)
                .unwrap_or_else(|e| panic!("bad frame {i}: reply not JSON ({e}): {reply}"));
            assert_eq!(
                parsed.get("ok").map(|j| j == &Json::Bool(false)),
                Some(true),
                "bad frame {i} must get ok:false, got {reply}"
            );
        }
    });

    let good_req = request_line(7, 1, 4);
    let b = barrier.clone();
    let good = std::thread::spawn(move || {
        let mut client = Client::connect(addr);
        b.wait();
        let mut first: Option<String> = None;
        for _ in 0..20 {
            let reply = client.roundtrip(&good_req);
            assert!(reply.contains("\"ok\": true"), "good conn disturbed: {reply}");
            // same request -> same reply, even with chaos on the other conn
            match &first {
                None => first = Some(reply),
                Some(f) => assert_eq!(&reply, f),
            }
        }
    });

    chaos.join().unwrap();
    good.join().unwrap();
    srv.finish();
}

#[test]
fn stats_counters_add_up() {
    let srv = TestServer::start(concurrent_opts(), "fused", 2);
    let mut client = Client::connect(srv.addr);
    let valid = 6u64;
    let invalid = 3u64;
    let mut total_atoms = 0u64;
    for k in 0..valid {
        let na = 1 + (k as usize % 2);
        total_atoms += na as u64;
        let reply = client.roundtrip(&request_line(900 + k, na, 4));
        assert!(reply.contains("\"ok\": true"));
    }
    for _ in 0..invalid {
        let reply = client.roundtrip("{\"num_atoms\": 2}");
        assert!(reply.contains("\"ok\": false"));
    }
    let stats_reply = client.roundtrip("{\"cmd\": \"stats\"}");
    let j = Json::parse(&stats_reply).expect("stats reply parses");
    let s = j.get("stats").expect("stats object");
    let get = |k: &str| s.get(k).and_then(Json::as_usize).unwrap() as u64;
    assert_eq!(get("replies_ok"), valid);
    assert_eq!(get("replies_err"), invalid);
    assert_eq!(get("stats_requests"), 1);
    assert_eq!(
        get("requests_total"),
        get("replies_ok") + get("replies_err") + get("stats_requests"),
        "frame accounting must close: {stats_reply}"
    );
    assert_eq!(get("atoms_computed"), total_atoms);
    assert!(get("jobs_dispatched") >= 1 && get("jobs_dispatched") <= valid);
    assert_eq!(get("workers"), 4);
    assert_eq!(get("connections_total"), 1);
    // without --plan the plan section reports the classic path
    assert_eq!(
        s.get("plan").and_then(|p| p.get("source")).and_then(Json::as_str),
        Some("off"),
        "{stats_reply}"
    );
    drop(client);
    let stats = srv.stats.clone();
    srv.finish();
    // in-process view agrees with the wire view
    assert_eq!(
        stats.replies_ok.load(std::sync::atomic::Ordering::Relaxed),
        valid
    );
}

#[test]
fn coalescer_merges_concurrent_single_atom_requests() {
    // generous window: all clients fire simultaneously after a barrier, so
    // the first request's hold window catches the others
    for attempt in 0..3 {
        let opts = ServeOptions {
            workers: 2,
            batch_window: std::time::Duration::from_millis(50),
            queue_depth: 64,
            max_batch_atoms: 32,
            ..ServeOptions::default()
        };
        let srv = TestServer::start(opts, "fused", 2);
        let addr = srv.addr;
        let barrier = Arc::new(Barrier::new(6));
        let handles: Vec<_> = (0..6)
            .map(|c| {
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let mut client = Client::connect(addr);
                    barrier.wait();
                    for k in 0..3u64 {
                        let reply =
                            client.roundtrip(&request_line(77 + c as u64 * 10 + k, 1, 4));
                        assert!(reply.contains("\"ok\": true"), "{reply}");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let coalesced = srv
            .stats
            .requests_coalesced
            .load(std::sync::atomic::Ordering::Relaxed);
        srv.finish();
        if coalesced >= 2 {
            return; // at least one merged dispatch observed
        }
        eprintln!("attempt {attempt}: no coalescing observed, retrying");
    }
    panic!("coalescer never merged concurrent single-atom requests");
}

#[test]
fn sharded_workers_are_byte_identical_and_observable() {
    // 12 atoms >= 2 * SHARD_MIN_ATOMS: this request takes the sharded path
    let big = request_line(42, 12, 4);
    let small = request_line(43, 1, 4);

    let serial = TestServer::start(sequential_opts(), "fused", 2);
    let mut client = Client::connect(serial.addr);
    let want_big = client.roundtrip(&big);
    let want_small = client.roundtrip(&small);
    drop(client);
    serial.finish();
    assert!(want_big.contains("\"ok\": true"), "{want_big}");

    let opts = ServeOptions {
        workers: 2,
        batch_window: std::time::Duration::ZERO,
        queue_depth: 64,
        max_batch_atoms: 32,
        shards: 3,
        ..ServeOptions::default()
    };
    // sharding lives in the factory now: the spec bakes it in, the
    // ServeOptions knob is what the stats report
    let idx = SnapIndex::new(2);
    let coeffs = SnapCoeffs::synthetic(2, idx.idxb_max, 42);
    let sharded_factory = EngineSpec::new(2)
        .engine("fused")
        .beta(coeffs.beta)
        .shards(3)
        .build_factory()
        .unwrap()
        .factory;
    let srv = TestServer::start_with_factory(opts, sharded_factory);
    let mut client = Client::connect(srv.addr);
    // intra-tile sharding must be byte-invisible to clients, for tiles
    // both above and below the fan-out floor
    assert_eq!(client.roundtrip(&big), want_big);
    assert_eq!(client.roundtrip(&small), want_small);
    let stats_reply = client.roundtrip("{\"cmd\": \"stats\"}");
    let j = Json::parse(&stats_reply).expect("stats reply parses");
    let s = j.get("stats").expect("stats object");
    // ... and observable from the outside: shard config + per-batch atoms
    assert_eq!(s.get("shards").and_then(Json::as_usize), Some(3), "{stats_reply}");
    assert_eq!(
        s.get("batch_atoms_max").and_then(Json::as_usize),
        Some(12),
        "{stats_reply}"
    );
    drop(client);
    srv.finish();
}

/// A server started from a persisted plan must (1) load it without
/// re-tuning — cache hit visible in stats — (2) expose the per-bucket
/// choices and dispatch counters over the wire, and (3) keep replies
/// byte-identical to the chosen serial variant (plans change speed, never
/// physics).
#[test]
fn planned_server_reports_plan_stats_and_stays_bitwise() {
    use repro::coordinator::server::PlanSetup;
    use repro::tune::{self, PlanEntry, PlanKey, ShapeBucket, TunedPlan};

    // persist a plan for this process's exact key: medium tiles on a
    // 2-way-sharded V7, everything else on the default fused entries
    let key = PlanKey::current(2);
    let mut plan = TunedPlan::default_plan(key);
    let v7 = repro::snap::variants::Variant::V7;
    plan.set_entry(
        ShapeBucket::Medium,
        PlanEntry { variant: v7, shards: 2, min_atoms_per_shard: 4 },
    );
    let path = std::env::temp_dir()
        .join(format!("repro_plan_server_test_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    tune::cache::save(&path, &plan).unwrap();

    // ground truth: the chosen variants served serially
    let small = request_line(50, 2, 4); // small bucket -> fused
    let medium = request_line(51, 12, 4); // medium bucket -> V7 (sharded 2x)
    let seq = TestServer::start(sequential_opts(), "fused", 2);
    let mut client = Client::connect(seq.addr);
    let want_small = client.roundtrip(&small);
    drop(client);
    seq.finish();
    let seq = TestServer::start(sequential_opts(), "V7", 2);
    let mut client = Client::connect(seq.addr);
    let want_medium = client.roundtrip(&medium);
    drop(client);
    seq.finish();

    // plan-driven server, built through the one construction site
    let idx = SnapIndex::new(2);
    let coeffs = SnapCoeffs::synthetic(2, idx.idxb_max, 42);
    let build = EngineSpec::new(2).beta(coeffs.beta).plan(&path).build_factory().unwrap();
    let resolution = build.plan.as_ref().expect("path spec resolves");
    assert!(
        resolution.selection.cache.is_hit(),
        "freshly saved plan must hit: {:?}",
        resolution.selection.cache
    );
    let opts = ServeOptions {
        workers: 2,
        batch_window: std::time::Duration::ZERO,
        queue_depth: 64,
        max_batch_atoms: 32,
        shards: 1,
        plan: Some(PlanSetup::from_selection(
            &resolution.selection,
            resolution.counters.clone(),
        )),
    };
    let planned_factory = build.factory;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stats = Arc::new(ServerStats::default());
    let (stop2, stats2) = (stop.clone(), stats.clone());
    let handle = std::thread::spawn(move || {
        serve_with_stats(listener, planned_factory, &opts, stop2, stats2)
    });

    let mut client = Client::connect(addr);
    assert_eq!(client.roundtrip(&small), want_small, "small bucket diverges from fused");
    assert_eq!(client.roundtrip(&medium), want_medium, "medium bucket diverges from V7");
    let stats_reply = client.roundtrip("{\"cmd\": \"stats\"}");
    let j = Json::parse(&stats_reply).expect("stats reply parses");
    let p = j.get("stats").and_then(|s| s.get("plan")).expect("plan section");
    assert_eq!(p.get("source").and_then(Json::as_str), Some(path.as_str()), "{stats_reply}");
    assert_eq!(p.get("cache").and_then(Json::as_str), Some("hit"), "{stats_reply}");
    assert_eq!(p.get("cache_hits").and_then(Json::as_usize), Some(1), "{stats_reply}");
    assert_eq!(p.get("cache_misses").and_then(Json::as_usize), Some(0), "{stats_reply}");
    let buckets = p.get("buckets").and_then(Json::as_arr).expect("buckets array");
    assert_eq!(buckets.len(), 3);
    let medium_bucket = buckets
        .iter()
        .find(|b| b.get("bucket").and_then(Json::as_str) == Some("medium"))
        .expect("medium bucket");
    assert_eq!(medium_bucket.get("variant").and_then(Json::as_str), Some("V7"));
    assert_eq!(medium_bucket.get("shards").and_then(Json::as_usize), Some(2));
    assert_eq!(
        medium_bucket.get("dispatches").and_then(Json::as_usize),
        Some(1),
        "{stats_reply}"
    );
    let small_bucket = buckets
        .iter()
        .find(|b| b.get("bucket").and_then(Json::as_str) == Some("small"))
        .expect("small bucket");
    assert_eq!(small_bucket.get("variant").and_then(Json::as_str), Some("VI-fused"));
    assert_eq!(small_bucket.get("dispatches").and_then(Json::as_usize), Some(1));

    drop(client);
    shutdown(addr, &stop);
    handle.join().unwrap().unwrap();
    std::fs::remove_file(&path).unwrap();
}

/// Engine fault isolation: dispatch failures — typed `EngineError`s *and*
/// contract-violating panics caught by the last-resort backstop — become
/// structured error replies on the offending request, are counted in the
/// `engine_errors` stat, and never kill the worker: the same worker keeps
/// serving good requests afterwards.
#[test]
fn engine_errors_are_structured_counted_and_isolated() {
    use repro::snap::engine::{EngineError, ForceEngine, TileInput, TileOutput};

    /// Stub engine: rij[0] == 666 -> typed Backend error; rij[0] == 777 ->
    /// panic (exercising the backstop); anything else computes.
    struct Booby;
    impl ForceEngine for Booby {
        fn name(&self) -> &str {
            "booby"
        }
        fn compute_into(
            &mut self,
            input: &TileInput,
            out: &mut TileOutput,
        ) -> Result<(), EngineError> {
            input.check()?;
            if input.rij[0] == 666.0 {
                return Err(EngineError::Backend("device fell over".into()));
            }
            assert!(input.rij[0] != 777.0, "boom");
            out.reset(input.num_atoms, input.num_nbor);
            out.ei.fill(1.5);
            Ok(())
        }
        fn footprint(&self, _na: usize, _nn: usize) -> repro::snap::memory::MemoryFootprint {
            repro::snap::memory::MemoryFootprint::new()
        }
    }

    let f: EngineFactory = Arc::new(|| Ok(Box::new(Booby) as Box<dyn ForceEngine>));
    let srv = TestServer::start_with_factory(
        ServeOptions { workers: 1, ..sequential_opts() },
        f,
    );
    let mut client = Client::connect(srv.addr);
    let req = |x0: f64| {
        format!(
            "{{\"num_atoms\": 1, \"num_nbor\": 1, \"rij\": [{x0}, 0, 0], \"mask\": [1]}}"
        )
    };
    // typed engine error -> structured reply through the normal error path
    let reply = client.roundtrip(&req(666.0));
    let parsed = Json::parse(&reply).expect("engine-error reply is valid JSON");
    assert_eq!(parsed.get("ok"), Some(&Json::Bool(false)), "{reply}");
    let msg = parsed.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.contains("device fell over"), "{msg}");
    // panicking engine -> the backstop converts, same structured shape
    let reply = client.roundtrip(&req(777.0));
    let parsed = Json::parse(&reply).expect("panic reply is valid JSON");
    assert_eq!(parsed.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert!(
        parsed.get("error").and_then(Json::as_str).unwrap().contains("panicked"),
        "{reply}"
    );
    // the single worker survived both: a good request still computes
    let reply = client.roundtrip(&req(1.0));
    assert!(reply.contains("\"ok\": true"), "worker died: {reply}");
    // and the stats separate engine failures from malformed-frame noise
    let reply = client.roundtrip("{\"num_atoms\": 2}"); // parse error, not engine
    assert!(reply.contains("\"ok\": false"));
    let stats_reply = client.roundtrip("{\"cmd\": \"stats\"}");
    let j = Json::parse(&stats_reply).expect("stats reply parses");
    let s = j.get("stats").expect("stats object");
    let get = |k: &str| s.get(k).and_then(Json::as_usize).unwrap();
    assert_eq!(get("engine_errors"), 2, "{stats_reply}");
    assert_eq!(get("replies_err"), 3, "{stats_reply}");
    assert_eq!(get("replies_ok"), 1, "{stats_reply}");
    drop(client);
    srv.finish();
}

#[test]
fn graceful_shutdown_reports_error_to_attached_sessions() {
    let srv = TestServer::start(sequential_opts(), "fused", 2);
    let mut client = Client::connect(srv.addr);
    let reply = client.roundtrip(&request_line(5, 1, 4));
    assert!(reply.contains("\"ok\": true"));
    // stop the pipeline while the session is still attached
    shutdown(srv.addr, &srv.stop);
    srv.handle.join().unwrap().unwrap();
    // the lingering session answers with a clean error, not a hang/crash
    let reply = client.roundtrip(&request_line(6, 1, 4));
    let parsed = Json::parse(&reply).expect("shutdown-path reply is valid JSON");
    assert_eq!(
        parsed.get("ok").map(|j| j == &Json::Bool(false)),
        Some(true),
        "{reply}"
    );
}

/// A deterministic typed request: `request_line`'s geometry plus an
/// `ielems`/`jelems` channel.  With all types 0 this must be byte-identical
/// to the untyped request on a multi-element server.
fn typed_request_line(seed: u64, na: usize, nn: usize, types_of: impl Fn(usize) -> i32) -> String {
    let base = request_line(seed, na, nn);
    let ielems: Vec<String> = (0..na).map(|a| types_of(a).to_string()).collect();
    let jelems: Vec<String> = (0..na * nn).map(|r| types_of(r).to_string()).collect();
    format!(
        "{}, \"ielems\": [{}], \"jelems\": [{}]}}",
        base.trim_end().trim_end_matches('}'),
        ielems.join(","),
        jelems.join(",")
    )
}

/// Factory for a 2-element (W–Be) server: element 0 is the degenerate
/// tungsten entry, so all-types-0 traffic must match the single-element
/// server byte for byte.
fn multi_factory(twojmax: usize) -> EngineFactory {
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic_multi(twojmax, idx.idxb_max, 2, 42);
    EngineSpec::new(twojmax)
        .engine("fused")
        .beta(coeffs.beta)
        .elements(coeffs.elements.clone())
        .build_factory()
        .unwrap()
        .factory
}

/// Wire-protocol multi-element contract: (a) legacy untyped requests to a
/// multi-element server get replies byte-identical to the single-element
/// server's (types omitted = element 0); (b) all-types-0 typed requests
/// are byte-identical too; (c) genuinely mixed types change the answer.
#[test]
fn typed_tiles_roundtrip_and_legacy_replies_stay_byte_identical() {
    let untyped = request_line(321, 3, 4);
    let zero_typed = typed_request_line(321, 3, 4, |_| 0);
    let mixed_typed = typed_request_line(321, 3, 4, |r| (r % 2) as i32);

    // ground truth from the classic single-element server
    let single = TestServer::start(sequential_opts(), "fused", 2);
    let mut client = Client::connect(single.addr);
    let want = client.roundtrip(&untyped);
    drop(client);
    single.finish();
    assert!(want.contains("\"ok\": true"), "{want}");

    let srv = TestServer::start_with_factory(sequential_opts(), multi_factory(2));
    let mut client = Client::connect(srv.addr);
    assert_eq!(
        client.roundtrip(&untyped),
        want,
        "legacy clients must get byte-identical replies from a multi-element server"
    );
    assert_eq!(
        client.roundtrip(&zero_typed),
        want,
        "all-types-0 typed tiles must be byte-identical to untyped"
    );
    let mixed = client.roundtrip(&mixed_typed);
    assert!(mixed.contains("\"ok\": true"), "{mixed}");
    assert_ne!(mixed, want, "mixed species must change the physics");
    drop(client);
    srv.finish();
}

/// Typed-request validation over the wire: wrong-length channels and
/// half-provided channels are structured errors; out-of-range types ride
/// the engine's BadShape path, bump `engine_errors`, and the worker
/// survives to serve the next request.
#[test]
fn typed_request_validation_is_structured_and_survivable() {
    let srv = TestServer::start_with_factory(
        ServeOptions { workers: 1, ..sequential_opts() },
        multi_factory(2),
    );
    let mut client = Client::connect(srv.addr);

    // wrong-length jelems: rejected at parse with a shape message
    let wrong_len =
        "{\"num_atoms\": 1, \"num_nbor\": 2, \"rij\": [1.5,0,0, 0,1.5,0], \"mask\": [1,1], \
         \"ielems\": [0], \"jelems\": [0]}";
    let reply = client.roundtrip(wrong_len);
    let parsed = Json::parse(&reply).expect("reply is valid JSON");
    assert_eq!(parsed.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert!(
        parsed.get("error").and_then(Json::as_str).unwrap().contains("jelems"),
        "{reply}"
    );

    // ielems without jelems: the channel is all-or-nothing
    let half = "{\"num_atoms\": 1, \"num_nbor\": 2, \"rij\": [1.5,0,0, 0,1.5,0], \
                \"mask\": [1,1], \"ielems\": [0]}";
    let reply = client.roundtrip(half);
    assert!(reply.contains("\"ok\": false"), "{reply}");
    assert!(reply.contains("together"), "{reply}");

    // non-integer types are a parse error, not a silent cast
    let fractional = "{\"num_atoms\": 1, \"num_nbor\": 2, \"rij\": [1.5,0,0, 0,1.5,0], \
                      \"mask\": [1,1], \"ielems\": [0.5], \"jelems\": [0, 0]}";
    let reply = client.roundtrip(fractional);
    assert!(reply.contains("\"ok\": false"), "{reply}");
    assert!(reply.contains("integer"), "{reply}");

    // out-of-range type: reaches the engine, comes back as BadShape,
    // bumps engine_errors
    let out_of_range =
        "{\"num_atoms\": 1, \"num_nbor\": 2, \"rij\": [1.5,0,0, 0,1.5,0], \"mask\": [1,1], \
         \"ielems\": [0], \"jelems\": [0, 5]}";
    let reply = client.roundtrip(out_of_range);
    let parsed = Json::parse(&reply).expect("reply is valid JSON");
    assert_eq!(parsed.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert!(
        parsed.get("error").and_then(Json::as_str).unwrap().contains("out of range"),
        "{reply}"
    );

    // the single worker survived: a good typed request still computes
    let good = typed_request_line(77, 1, 4, |r| (r % 2) as i32);
    let reply = client.roundtrip(&good);
    assert!(reply.contains("\"ok\": true"), "worker died: {reply}");

    let stats_reply = client.roundtrip("{\"cmd\": \"stats\"}");
    let j = Json::parse(&stats_reply).expect("stats reply parses");
    let s = j.get("stats").expect("stats object");
    let get = |k: &str| s.get(k).and_then(Json::as_usize).unwrap();
    assert_eq!(
        get("engine_errors"),
        1,
        "only the out-of-range type is an engine error: {stats_reply}"
    );
    assert_eq!(get("replies_err"), 4, "{stats_reply}");
    assert_eq!(get("replies_ok"), 1, "{stats_reply}");
    drop(client);
    srv.finish();
}

/// The coalescer never merges typed with untyped traffic: concurrent
/// mixed-profile clients with a wide-open merge window all get replies
/// byte-identical to solo serving (a wrong merge would either retype a
/// tile or panic the batch, both observable).
#[test]
fn coalescer_never_merges_mismatched_species_profiles() {
    let untyped_req = request_line(611, 1, 4);
    let typed_req = typed_request_line(612, 1, 4, |r| (r % 2) as i32);

    // solo ground truth
    let solo = TestServer::start_with_factory(sequential_opts(), multi_factory(2));
    let mut client = Client::connect(solo.addr);
    let want_untyped = client.roundtrip(&untyped_req);
    let want_typed = client.roundtrip(&typed_req);
    drop(client);
    solo.finish();
    assert!(want_typed.contains("\"ok\": true"), "{want_typed}");

    // generous window + barrier: maximal merge pressure across profiles
    let opts = ServeOptions {
        workers: 2,
        batch_window: std::time::Duration::from_millis(40),
        queue_depth: 64,
        max_batch_atoms: 32,
        ..ServeOptions::default()
    };
    let srv = TestServer::start_with_factory(opts, multi_factory(2));
    let addr = srv.addr;
    let barrier = Arc::new(Barrier::new(6));
    let handles: Vec<_> = (0..6)
        .map(|c| {
            let barrier = barrier.clone();
            let req = if c % 2 == 0 { untyped_req.clone() } else { typed_req.clone() };
            let want = if c % 2 == 0 { want_untyped.clone() } else { want_typed.clone() };
            std::thread::spawn(move || {
                let mut client = Client::connect(addr);
                barrier.wait();
                for k in 0..4 {
                    let reply = client.roundtrip(&req);
                    assert_eq!(reply, want, "client {c} rep {k}: profile-mixed merge detected");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    srv.finish();
}

/// 4 workers + 8 clients must beat 1 worker by >= 2x on a multi-core
/// machine.  Opt-in (like REPRO_HEAVY_TESTS) because CI containers and
/// laptops under load make wall-clock assertions flaky.
#[test]
fn four_workers_double_throughput_over_one() {
    if std::env::var("REPRO_PERF_TESTS").is_err() {
        eprintln!("skipping perf assertion (set REPRO_PERF_TESTS=1 to run)");
        return;
    }
    let run = |workers: usize| -> f64 {
        let opts = ServeOptions {
            workers,
            batch_window: std::time::Duration::from_micros(100),
            queue_depth: 64,
            max_batch_atoms: 32,
            ..ServeOptions::default()
        };
        // 2J=8 single-atom tiles: enough compute per request that the
        // engine, not socket I/O, is the bottleneck
        let srv = TestServer::start(opts, "fused", 8);
        let addr = srv.addr;
        let barrier = Arc::new(Barrier::new(9));
        let handles: Vec<_> = (0..8)
            .map(|c| {
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let mut client = Client::connect(addr);
                    barrier.wait();
                    for k in 0..25u64 {
                        let reply =
                            client.roundtrip(&request_line(c as u64 * 100 + k, 1, 12));
                        assert!(reply.contains("\"ok\": true"));
                    }
                })
            })
            .collect();
        barrier.wait();
        let t0 = std::time::Instant::now();
        for h in handles {
            h.join().unwrap();
        }
        let secs = t0.elapsed().as_secs_f64();
        srv.finish();
        200.0 / secs
    };
    let rps1 = run(1);
    let rps4 = run(4);
    eprintln!("1 worker: {rps1:.1} req/s, 4 workers: {rps4:.1} req/s");
    assert!(
        rps4 >= 2.0 * rps1,
        "expected >= 2x speedup with 4 workers: {rps1:.1} -> {rps4:.1} req/s"
    );
}
