//! Intra-tile hierarchical parallelism, end to end through the public API:
//! engine reuse across tile sizes, the sharded factory, and the env-gated
//! speedup gate on the paper's tungsten workload.  (The exhaustive bitwise
//! shard-count matrix and the pool index-order tests live next to the code
//! as unit tests in `snap/sharded.rs` and `util/parallel.rs`.)

use repro::bench::{grind, Workload};
use repro::config::EngineSpec;
use repro::snap::coeff::SnapCoeffs;
use repro::snap::sharded::ShardedEngine;
use repro::snap::{ForceEngine, SnapIndex, SnapParams, TileInput};
use repro::util::{ThreadPool, XorShift};

fn fused_factory(twojmax: usize) -> repro::snap::EngineFactory {
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    EngineSpec::new(twojmax)
        .engine("fused")
        .beta(coeffs.beta)
        .build_factory()
        .unwrap()
        .factory
}

/// Random tile with ~25% padded neighbor slots and (for na > 2) one fully
/// padded atom row, so the mask contract crosses shard boundaries.
fn tile(seed: u64, na: usize, nn: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rng = XorShift::new(seed);
    let mut rij = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..na * nn {
        for _ in 0..3 {
            rij.push(rng.uniform(-2.4, 2.4));
        }
        mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
    }
    if na > 2 {
        for slot in 0..nn {
            mask[2 * nn + slot] = 0.0;
        }
    }
    (rij, mask)
}

#[test]
fn sharded_engine_is_reusable_across_tile_sizes() {
    // the server reuses one engine per worker across requests of varying
    // size; shard planning must re-adapt every call
    let factory = fused_factory(2);
    let mut serial = factory().unwrap();
    let mut sharded = ShardedEngine::new(&factory, 3).unwrap();
    for (seed, na, nn) in [(1u64, 9usize, 4usize), (2, 1, 4), (3, 12, 4), (4, 2, 6)] {
        let (rij, mask) = tile(seed, na, nn);
        let inp = TileInput { num_atoms: na, num_nbor: nn, rij: &rij, mask: &mask, elems: None };
        let want = serial.compute(&inp);
        let got = sharded.compute(&inp);
        assert_eq!(want.ei, got.ei, "na={na}");
        assert_eq!(want.dedr, got.dedr, "na={na}");
    }
}

#[test]
fn sharded_spec_produces_named_wrappers() {
    let idx = SnapIndex::new(2);
    let coeffs = SnapCoeffs::synthetic(2, idx.idxb_max, 42);
    let f = EngineSpec::new(2)
        .engine("fused")
        .beta(coeffs.beta)
        .shards(4)
        .build_factory()
        .unwrap()
        .factory;
    let a = f().unwrap();
    let b = f().unwrap();
    assert_eq!(a.name(), "sharded4x-VI-fused");
    assert_eq!(a.name(), b.name());
}

/// 4 shards must beat 1 shard by >= 1.5x on the tungsten workload.  Opt-in
/// (REPRO_PERF_TESTS=1) because wall-clock assertions are flaky on busy
/// hosts, and it needs real cores: run with REPRO_THREADS=4 (the global
/// pool then has 3 workers + the submitting lane).
#[test]
fn four_shards_speed_up_tungsten_by_1_5x() {
    if std::env::var("REPRO_PERF_TESTS").is_err() {
        eprintln!("skipping perf assertion (set REPRO_PERF_TESTS=1 to run)");
        return;
    }
    let pool_workers = ThreadPool::global().workers();
    if pool_workers < 3 {
        eprintln!(
            "skipping: global pool has {pool_workers} workers, need >= 3 \
             (set REPRO_THREADS=4 and run on a >= 4-core host)"
        );
        return;
    }
    let twojmax = 8;
    let params = SnapParams::with_twojmax(twojmax);
    let w = Workload::tungsten(6, params.rcut()); // 432 atoms, 26 neighbors
    let factory = fused_factory(twojmax);
    let run = |shards: usize| {
        let mut engine = ShardedEngine::new(&factory, shards).unwrap();
        grind(&mut engine, &w, 1, 3).secs_per_step
    };
    let serial = run(1);
    let sharded = run(4);
    let speedup = serial / sharded;
    eprintln!(
        "tungsten grind: 1 shard {:.1} ms, 4 shards {:.1} ms -> {speedup:.2}x",
        serial * 1e3,
        sharded * 1e3
    );
    assert!(
        speedup >= 1.5,
        "expected >= 1.5x with 4 shards on 4 lanes, got {speedup:.2}x"
    );
}
