//! VII-simd differential conformance: the lane-parallel engine against
//! `VI-fused` across ragged lane tails, masked-heavy tiles, mixed-species
//! tiles, and the sharded wrapper.
//!
//! **Equivalence contract** (the tolerance documentation the engine ladder
//! requires): VII-simd's lanes are *atoms* — lane `l` of every batched
//! kernel executes exactly the scalar engine's floating-point sequence for
//! atom `block*LANES + l`, and no cross-lane reduction exists anywhere in
//! the U accumulate, the Y contraction, the energy sum, or the fused dE
//! stream.  The operation order is therefore preserved per atom, and every
//! comparison below asserts **bitwise** equality (`assert_eq!` on `f64`,
//! i.e. IEEE `==`; the one legal artifact — masked lanes contributing
//! exact ±0.0 terms whose zero *sign* may differ — is absorbed by `==`,
//! which treats +0.0 and -0.0 as equal).  The bounded fallback the ladder
//! would allow (≤1e-12 relative) is deliberately *not* used: if a future
//! refactor introduces lane-order reassociation, these tests are where
//! the contract must be relaxed — consciously, not by accident.

use repro::snap::engine::{EngineFactory, ForceEngine, TileElems, TileInput};
use repro::snap::variants::Variant;
use repro::snap::wigner::LANES;
use repro::snap::{SnapIndex, SnapParams, TileOutput};
use repro::util::XorShift;
use std::sync::Arc;

/// A random padded tile with a controllable masked-neighbor fraction.
struct Tile {
    na: usize,
    nn: usize,
    rij: Vec<f64>,
    mask: Vec<f64>,
    ielems: Vec<i32>,
    jelems: Vec<i32>,
}

impl Tile {
    fn random(seed: u64, na: usize, nn: usize, masked_frac: f64, nelems: i32) -> Tile {
        let mut rng = XorShift::new(seed);
        let mut rij = Vec::new();
        let mut mask = Vec::new();
        let mut jelems = Vec::new();
        for row in 0..na * nn {
            loop {
                let v = [
                    rng.uniform(-2.4, 2.4),
                    rng.uniform(-2.4, 2.4),
                    rng.uniform(-2.4, 2.4),
                ];
                if (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt() > 0.4 {
                    rij.extend_from_slice(&v);
                    break;
                }
            }
            mask.push(if rng.next_f64() > masked_frac { 1.0 } else { 0.0 });
            jelems.push((row as i32 * 7 + 3) % nelems);
        }
        let ielems = (0..na).map(|a| (a as i32 * 5 + 1) % nelems).collect();
        Tile { na, nn, rij, mask, ielems, jelems }
    }

    fn untyped(&self) -> TileInput<'_> {
        TileInput {
            num_atoms: self.na,
            num_nbor: self.nn,
            rij: &self.rij,
            mask: &self.mask,
            elems: None,
        }
    }

    fn typed(&self) -> TileInput<'_> {
        TileInput {
            num_atoms: self.na,
            num_nbor: self.nn,
            rij: &self.rij,
            mask: &self.mask,
            elems: Some(TileElems { ielems: &self.ielems, jelems: &self.jelems }),
        }
    }
}

fn beta_for(twojmax: usize) -> Vec<f64> {
    let idx = SnapIndex::new(twojmax);
    let mut rng = XorShift::new(4242);
    (0..idx.idxb_max).map(|_| rng.normal()).collect()
}

fn build(v: Variant, twojmax: usize) -> Box<dyn ForceEngine> {
    let idx = Arc::new(SnapIndex::new(twojmax));
    v.build(SnapParams::with_twojmax(twojmax), idx, beta_for(twojmax))
}

/// Bitwise comparison per the contract in the module docs: IEEE `==` on
/// every energy and every dE/dr component.
fn assert_bitwise(want: &TileOutput, got: &TileOutput, what: &str) {
    assert_eq!(want.ei, got.ei, "{what}: ei diverges");
    assert_eq!(want.dedr, got.dedr, "{what}: dedr diverges");
}

/// Lane-width sweep: `na mod LANES ∈ {0, 1, LANES-1}` at one and several
/// blocks, plus a sub-lane tile — the ragged-tail cases where AoSoA
/// padding lanes are live in every batched call.
#[test]
fn ragged_lane_tails_are_bitwise_fused() {
    for twojmax in [2usize, 3] {
        for na in [
            1,
            LANES - 1,
            LANES,
            LANES + 1,
            2 * LANES - 1,
            2 * LANES,
            3 * LANES + 1,
        ] {
            let tile = Tile::random(100 + na as u64, na, 6, 0.25, 1);
            let want = build(Variant::Fused, twojmax).compute(&tile.untyped());
            let got = build(Variant::FusedSimd, twojmax).compute(&tile.untyped());
            assert_bitwise(&want, &got, &format!("2J={twojmax} na={na}"));
        }
    }
}

/// Masked-neighbor-heavy tiles: most lanes of most batched calls are
/// inactive, including whole neighbor slots with no real pair in a block
/// (the batch is skipped, like the scalar engine's per-pair skip) and a
/// fully masked tile (every output must be exactly zero on both engines).
#[test]
fn masked_neighbor_heavy_tiles_are_bitwise_fused() {
    let twojmax = 2usize;
    for (seed, na, masked_frac) in [(7u64, 9usize, 0.9), (8, 17, 0.95), (9, 12, 1.0)] {
        let tile = Tile::random(seed, na, 8, masked_frac, 1);
        let want = build(Variant::Fused, twojmax).compute(&tile.untyped());
        let got = build(Variant::FusedSimd, twojmax).compute(&tile.untyped());
        assert_bitwise(&want, &got, &format!("na={na} masked={masked_frac}"));
        if masked_frac == 1.0 {
            assert!(got.dedr.iter().all(|&d| d == 0.0), "fully masked tile");
        }
    }
}

/// Mixed-species tiles: the per-pair cutoffs/weights and per-element beta
/// blocks flow through the batched geometry pack and the per-lane beta
/// offsets of the batched Y stage.
#[test]
fn multi_element_tiles_are_bitwise_fused() {
    use repro::snap::coeff::SnapCoeffs;
    let twojmax = 3usize;
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = SnapCoeffs::synthetic_multi(twojmax, idx.idxb_max, 2, 42);
    let params = SnapParams::with_twojmax(twojmax);
    let mut fused = Variant::Fused.build_multi(
        params,
        idx.clone(),
        coeffs.beta.clone(),
        coeffs.elements.clone(),
    );
    let mut simd = Variant::FusedSimd.build_multi(
        params,
        idx.clone(),
        coeffs.beta.clone(),
        coeffs.elements.clone(),
    );
    for (seed, na) in [(21u64, 5usize), (22, LANES + 1), (23, 2 * LANES - 1)] {
        let tile = Tile::random(seed, na, 6, 0.25, 2);
        let want = fused.compute(&tile.typed());
        let got = simd.compute(&tile.typed());
        assert_bitwise(&want, &got, &format!("typed na={na}"));
    }
}

/// ShardedEngine over VII-simd: sub-tile stitching re-blocks each shard's
/// atoms from zero, so shard-local padding differs from the serial run —
/// per-atom math must not.  Serial VII-simd, sharded VII-simd, and serial
/// VI-fused must all agree bitwise (the documented contract; no relaxed
/// stitching tolerance is needed).
#[test]
fn sharded_over_simd_stitches_bitwise() {
    use repro::snap::sharded::ShardedEngine;
    let twojmax = 2usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let beta = beta_for(twojmax);
    let tile = Tile::random(31, 2 * LANES + 1, 6, 0.3, 1); // ragged per shard
    let factory: EngineFactory = {
        let idx = idx.clone();
        let beta = beta.clone();
        Arc::new(move || Ok(Variant::FusedSimd.build(params, idx.clone(), beta.clone())))
    };
    let serial = Variant::FusedSimd
        .build(params, idx.clone(), beta.clone())
        .compute(&tile.untyped());
    let fused = Variant::Fused
        .build(params, idx.clone(), beta.clone())
        .compute(&tile.untyped());
    for shards in [2usize, 3] {
        let mut sharded = ShardedEngine::new(&factory, shards).unwrap();
        let got = sharded.compute(&tile.untyped());
        assert_bitwise(&serial, &got, &format!("{shards}-sharded vs serial"));
        assert_bitwise(&fused, &got, &format!("{shards}-sharded vs VI-fused"));
    }
}

/// The rung is discoverable everywhere an engine can be named.
#[test]
fn simd_rung_is_registered() {
    assert!(Variant::ladder().contains(&Variant::FusedSimd));
    assert_eq!(Variant::FusedSimd.label(), "VII-simd");
    assert_eq!(Variant::from_label("VII-simd"), Some(Variant::FusedSimd));
    assert_eq!(Variant::from_label("simd"), Some(Variant::FusedSimd));
    let e = repro::config::EngineSpec::new(2)
        .engine("VII-simd")
        .beta(beta_for(2))
        .build()
        .unwrap();
    assert_eq!(e.name(), "VII-simd");
}
