//! Multi-element differential conformance suite.
//!
//! The repo's value is the bitwise-diffable engine ladder, so the
//! multi-element path must prove two things at once:
//!
//! 1. **The single-element fast path is untouched** — a typed tile with
//!    all types = 0 and the degenerate per-element table produces bytes
//!    identical to the untyped path, across ladder ∪ fig1, serial and
//!    sharded, and an untyped tile on a 2-element engine is byte-identical
//!    to the single-element engine (legacy clients see nothing).
//! 2. **The mixed-species math is right** — every ladder formulation
//!    agrees on mixed tiles, forces match finite differences of the
//!    energy, atom-order permutations commute bitwise, and the usual
//!    rotation/translation invariances hold on the B2 W–Be workload.

use repro::config::EngineSpec;
use repro::coordinator::ForceField;
use repro::md::{lattice, NeighborList};
use repro::snap::coeff::SnapCoeffs;
use repro::snap::engine::{EngineFactory, ForceEngine, TileElems, TileInput};
use repro::snap::params::ElementTable;
use repro::snap::sharded::ShardedEngine;
use repro::snap::variants::Variant;
use repro::snap::{SnapIndex, SnapParams};
use repro::util::XorShift;
use std::sync::Arc;

const WBE_COEFF: &str = include_str!("fixtures/wbe.snapcoeff");
const WBE_PARAM: &str = include_str!("fixtures/wbe.snapparam");

/// A random tile plus a deterministic 2-element type assignment.
struct TypedTile {
    na: usize,
    nn: usize,
    rij: Vec<f64>,
    mask: Vec<f64>,
    ielems: Vec<i32>,
    jelems: Vec<i32>,
}

impl TypedTile {
    fn random(seed: u64, na: usize, nn: usize, nelems: i32) -> TypedTile {
        let mut rng = XorShift::new(seed);
        let mut rij = Vec::new();
        let mut mask = Vec::new();
        let mut jelems = Vec::new();
        for row in 0..na * nn {
            loop {
                let v = [
                    rng.uniform(-2.4, 2.4),
                    rng.uniform(-2.4, 2.4),
                    rng.uniform(-2.4, 2.4),
                ];
                if (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt() > 0.4 {
                    rij.extend_from_slice(&v);
                    break;
                }
            }
            mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
            jelems.push((row as i32 * 7 + 3) % nelems);
        }
        let ielems = (0..na).map(|a| (a as i32 * 5 + 1) % nelems).collect();
        TypedTile { na, nn, rij, mask, ielems, jelems }
    }

    fn typed(&self) -> TileInput<'_> {
        TileInput {
            num_atoms: self.na,
            num_nbor: self.nn,
            rij: &self.rij,
            mask: &self.mask,
            elems: Some(TileElems { ielems: &self.ielems, jelems: &self.jelems }),
        }
    }

    fn untyped(&self) -> TileInput<'_> {
        TileInput {
            num_atoms: self.na,
            num_nbor: self.nn,
            rij: &self.rij,
            mask: &self.mask,
            elems: None,
        }
    }
}

fn wbe_coeffs(twojmax: usize) -> SnapCoeffs {
    SnapCoeffs::synthetic_multi(twojmax, SnapIndex::new(twojmax).idxb_max, 2, 42)
}

fn multi_factory(twojmax: usize, v: Variant, coeffs: &SnapCoeffs) -> EngineFactory {
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let beta = coeffs.beta.clone();
    let elems = coeffs.elements.clone();
    Arc::new(move || Ok(v.build_multi(params, idx.clone(), beta.clone(), elems.clone())))
}

/// (1a) With the degenerate table, an all-types-0 typed tile is
/// bit-identical to the untyped tile across the whole ladder ∪ fig1 set —
/// the multi-element machinery costs the single-element path nothing, not
/// even an ULP.
#[test]
fn all_zero_types_are_bitwise_identical_to_untyped_across_the_ladder() {
    let twojmax = 3usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let beta = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42).beta;
    let tile = TypedTile::random(11, 5, 6, 1); // nelems 1 -> all types 0
    assert!(tile.ielems.iter().all(|&t| t == 0));
    for v in Variant::ladder().iter().chain(Variant::fig1()) {
        let mut eng = v.build(params, idx.clone(), beta.clone());
        let untyped = eng.compute(&tile.untyped());
        let typed = eng.compute(&tile.typed());
        assert_eq!(untyped.ei, typed.ei, "{v:?}: typed all-0 ei diverges");
        assert_eq!(untyped.dedr, typed.dedr, "{v:?}: typed all-0 dedr diverges");
    }
}

/// (1b) Same guarantee under the sharded wrapper (the channel is sliced
/// per shard), including an uneven last shard.
#[test]
fn all_zero_types_are_bitwise_identical_under_the_sharded_wrapper() {
    let twojmax = 2usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let beta = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42).beta;
    let factory: EngineFactory = {
        let idx = idx.clone();
        let beta = beta.clone();
        Arc::new(move || Ok(Variant::Fused.build(params, idx.clone(), beta.clone())))
    };
    let tile = TypedTile::random(13, 7, 5, 1);
    let mut serial = factory().unwrap();
    let want = serial.compute(&tile.untyped());
    for shards in [2usize, 3] {
        let mut eng = ShardedEngine::new(&factory, shards).unwrap();
        let typed = eng.compute(&tile.typed());
        let untyped = eng.compute(&tile.untyped());
        assert_eq!(want.ei, typed.ei, "shards={shards}: typed ei diverges");
        assert_eq!(want.dedr, typed.dedr, "shards={shards}: typed dedr diverges");
        assert_eq!(want.ei, untyped.ei, "shards={shards}: untyped ei diverges");
        assert_eq!(want.dedr, untyped.dedr, "shards={shards}: untyped dedr diverges");
    }
}

/// (1c) An *untyped* tile on a 2-element engine resolves to element 0 and
/// is byte-identical to the single-element engine built from element 0's
/// block — the wire-level "legacy clients keep byte-identical replies"
/// guarantee, at the engine layer.
#[test]
fn untyped_tiles_on_a_two_element_engine_match_the_single_element_engine() {
    let twojmax = 2usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let single = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    let multi = wbe_coeffs(twojmax);
    assert_eq!(multi.beta_block(0), &single.beta[..]);
    let tile = TypedTile::random(17, 4, 5, 1);
    for v in [Variant::V0Baseline, Variant::V7, Variant::Fused] {
        let mut a = v.build(params, idx.clone(), single.beta.clone());
        let mut b = v.build_multi(
            params,
            idx.clone(),
            multi.beta.clone(),
            multi.elements.clone(),
        );
        let wa = a.compute(&tile.untyped());
        let wb = b.compute(&tile.untyped());
        assert_eq!(wa.ei, wb.ei, "{v:?}: multi-engine untyped ei diverges");
        assert_eq!(wa.dedr, wb.dedr, "{v:?}: multi-engine untyped dedr diverges");
    }
}

/// (2a) Every ladder formulation — materialized Zlist baseline, the
/// adjoint V-ladder, the fused section-VI kernels, AoSoA — agrees on a
/// genuinely mixed-species tile: per-pair cutoffs, density weights and
/// per-element beta blocks are implemented identically everywhere.
#[test]
fn every_ladder_step_agrees_on_a_mixed_species_tile() {
    let twojmax = 3usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = wbe_coeffs(twojmax);
    let tile = TypedTile::random(19, 4, 6, 2);
    assert!(tile.ielems.iter().any(|&t| t == 1), "tile must mix species");
    let mut reference: Option<repro::snap::TileOutput> = None;
    for v in Variant::ladder().iter().chain(Variant::fig1()) {
        let mut eng =
            v.build_multi(params, idx.clone(), coeffs.beta.clone(), coeffs.elements.clone());
        let out = eng.compute(&tile.typed());
        if let Some(want) = &reference {
            for (i, (a, b)) in want.ei.iter().zip(out.ei.iter()).enumerate() {
                assert!((a - b).abs() < 1e-9 * (1.0 + a.abs()), "{v:?} ei[{i}]: {a} vs {b}");
            }
            for (i, (a, b)) in want.dedr.iter().zip(out.dedr.iter()).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                    "{v:?} dedr[{i}]: {a} vs {b}"
                );
            }
        } else {
            reference = Some(out);
        }
    }
}

/// (2b) Mixed-tile forces are the exact derivative of the mixed-tile
/// energy — the strongest check that the weights and per-pair cutoffs
/// enter the U accumulation and its adjoint consistently.
#[test]
fn mixed_species_forces_match_finite_difference_of_energy() {
    let twojmax = 3usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = wbe_coeffs(twojmax);
    let mut tile = TypedTile::random(23, 2, 5, 2);
    let mut eng = Variant::V0Baseline.build_multi(
        params,
        idx.clone(),
        coeffs.beta.clone(),
        coeffs.elements.clone(),
    );
    let out = eng.compute(&tile.typed());
    let h = 1e-6;
    for probe in [(0usize, 1usize, 0usize), (1, 3, 2), (0, 4, 1), (1, 0, 0)] {
        let (a, n, k) = probe;
        if tile.mask[a * tile.nn + n] == 0.0 {
            continue;
        }
        let o = (a * tile.nn + n) * 3 + k;
        let orig = tile.rij[o];
        tile.rij[o] = orig + h;
        let ep: f64 = eng.compute(&tile.typed()).ei.iter().sum();
        tile.rij[o] = orig - h;
        let em: f64 = eng.compute(&tile.typed()).ei.iter().sum();
        tile.rij[o] = orig;
        let fd = (ep - em) / (2.0 * h);
        let got = out.dedr[o];
        assert!(
            (fd - got).abs() < 1e-6 * (1.0 + got.abs()),
            "probe {probe:?}: fd={fd} got={got}"
        );
    }
}

/// (2c) Permuting the atom order of a 2-element tile permutes the outputs
/// bitwise: per-atom arithmetic is order-independent in every engine,
/// including AoSoA lane packing and sharded atom ranges.
#[test]
fn two_element_tile_is_permutation_consistent() {
    let twojmax = 2usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = wbe_coeffs(twojmax);
    let tile = TypedTile::random(29, 5, 4, 2);
    // a fixed permutation of the atom rows
    let perm: [usize; 5] = [3, 0, 4, 1, 2];
    let mut permuted = TypedTile {
        na: tile.na,
        nn: tile.nn,
        rij: vec![0.0; tile.rij.len()],
        mask: vec![0.0; tile.mask.len()],
        ielems: vec![0; tile.na],
        jelems: vec![0; tile.na * tile.nn],
    };
    for (dst, &src) in perm.iter().enumerate() {
        let nn = tile.nn;
        permuted.rij[dst * nn * 3..(dst + 1) * nn * 3]
            .copy_from_slice(&tile.rij[src * nn * 3..(src + 1) * nn * 3]);
        permuted.mask[dst * nn..(dst + 1) * nn]
            .copy_from_slice(&tile.mask[src * nn..(src + 1) * nn]);
        permuted.jelems[dst * nn..(dst + 1) * nn]
            .copy_from_slice(&tile.jelems[src * nn..(src + 1) * nn]);
        permuted.ielems[dst] = tile.ielems[src];
    }
    let engines: Vec<Box<dyn ForceEngine>> = vec![
        Variant::V0Baseline.build_multi(
            params,
            idx.clone(),
            coeffs.beta.clone(),
            coeffs.elements.clone(),
        ),
        Variant::V5.build_multi(params, idx.clone(), coeffs.beta.clone(), coeffs.elements.clone()),
        Variant::Fused.build_multi(
            params,
            idx.clone(),
            coeffs.beta.clone(),
            coeffs.elements.clone(),
        ),
        Variant::FusedAosoa.build_multi(
            params,
            idx.clone(),
            coeffs.beta.clone(),
            coeffs.elements.clone(),
        ),
        Box::new(ShardedEngine::new(&multi_factory(twojmax, Variant::Fused, &coeffs), 3).unwrap()),
    ];
    for mut eng in engines {
        let base = eng.compute(&tile.typed());
        let perm_out = eng.compute(&permuted.typed());
        let name = eng.name().to_string();
        for (dst, &src) in perm.iter().enumerate() {
            assert_eq!(base.ei[src], perm_out.ei[dst], "{name}: ei not permutation-consistent");
            let nn = tile.nn;
            assert_eq!(
                &base.dedr[src * nn * 3..(src + 1) * nn * 3],
                &perm_out.dedr[dst * nn * 3..(dst + 1) * nn * 3],
                "{name}: dedr not permutation-consistent"
            );
        }
    }
}

/// (2d) Sharded multi-element dispatch is bit-identical to serial — the
/// types channel slices exactly like rij/mask.
#[test]
fn sharded_multi_element_is_bitwise_identical_to_serial() {
    let twojmax = 2usize;
    let coeffs = wbe_coeffs(twojmax);
    let factory = multi_factory(twojmax, Variant::Fused, &coeffs);
    let mut serial = factory().unwrap();
    for (seed, na, nn) in [(31u64, 13usize, 5usize), (37, 6, 4), (41, 2, 3)] {
        let tile = TypedTile::random(seed, na, nn, 2);
        let want = serial.compute(&tile.typed());
        for shards in [2usize, 3, 7] {
            let mut eng = ShardedEngine::new(&factory, shards).unwrap();
            let got = eng.compute(&tile.typed());
            assert_eq!(want.ei, got.ei, "na={na} shards={shards}: ei");
            assert_eq!(want.dedr, got.dedr, "na={na} shards={shards}: dedr");
        }
    }
}

/// (2e) Rotation invariance on a mixed tile: the bispectrum is rotation
/// invariant per element pair, so energies survive a rigid rotation of
/// every displacement even with per-pair cutoffs and weights in play.
#[test]
fn mixed_species_energy_is_rotation_invariant() {
    let twojmax = 3usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = wbe_coeffs(twojmax);
    for seed in 0..6u64 {
        let mut rng = XorShift::new(8000 + seed);
        let tile = TypedTile::random(43 + seed, 3, 6, 2);
        // random rotation (axis-angle, Rodrigues)
        let axis = {
            let v = [rng.normal(), rng.normal(), rng.normal()];
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            [v[0] / n, v[1] / n, v[2] / n]
        };
        let ang = rng.uniform(0.3, 2.8);
        let (c, s) = (ang.cos(), ang.sin());
        let rot = |v: [f64; 3]| -> [f64; 3] {
            let dot = axis[0] * v[0] + axis[1] * v[1] + axis[2] * v[2];
            let cross = [
                axis[1] * v[2] - axis[2] * v[1],
                axis[2] * v[0] - axis[0] * v[2],
                axis[0] * v[1] - axis[1] * v[0],
            ];
            [
                v[0] * c + cross[0] * s + axis[0] * dot * (1.0 - c),
                v[1] * c + cross[1] * s + axis[1] * dot * (1.0 - c),
                v[2] * c + cross[2] * s + axis[2] * dot * (1.0 - c),
            ]
        };
        let mut rotated = TypedTile {
            na: tile.na,
            nn: tile.nn,
            rij: vec![0.0; tile.rij.len()],
            mask: tile.mask.clone(),
            ielems: tile.ielems.clone(),
            jelems: tile.jelems.clone(),
        };
        for i in 0..tile.rij.len() / 3 {
            let v = rot([tile.rij[3 * i], tile.rij[3 * i + 1], tile.rij[3 * i + 2]]);
            rotated.rij[3 * i..3 * i + 3].copy_from_slice(&v);
        }
        let mut eng = Variant::Fused.build_multi(
            params,
            idx.clone(),
            coeffs.beta.clone(),
            coeffs.elements.clone(),
        );
        let a = eng.compute(&tile.typed());
        let b = eng.compute(&rotated.typed());
        for (x, y) in a.ei.iter().zip(b.ei.iter()) {
            assert!(
                (x - y).abs() < 1e-8 * (1.0 + x.abs()),
                "seed {seed}: E {x} vs rotated {y}"
            );
        }
    }
}

/// (2f) End to end on the B2 W–Be workload through `ForceField`: forces
/// balance (translation invariance of the total energy), everything is
/// finite, and rigidly translating the whole cell (with periodic
/// wrapping) leaves per-atom energies and forces unchanged.
#[test]
fn wbe_alloy_forces_balance_and_are_translation_invariant() {
    let coeffs = SnapCoeffs::synthetic_multi(2, SnapIndex::new(2).idxb_max, 2, 42);
    let params = coeffs.params;
    let cutoff = coeffs.elements.max_cutoff(params.rcutfac).max(params.rcut());
    let build_field = || {
        EngineSpec::new(2)
            .engine("fused")
            .beta(coeffs.beta.clone())
            .elements(coeffs.elements.clone())
            .build()
            .unwrap()
    };

    let mut s = lattice::wbe_alloy(3);
    let mut rng = XorShift::new(51);
    s.jitter(0.08, &mut rng);
    s.wrap_all();
    let nl = NeighborList::build_cells(&s, cutoff);
    let mut ff = ForceField::new(build_field(), 16, nl.max_count().max(1));
    let r = ff.compute(&s, &nl).unwrap();
    assert!(r.forces.iter().all(|f| f.is_finite()));
    assert!(r.ei.iter().all(|e| e.is_finite()));
    for k in 0..3 {
        let total: f64 = (0..s.natoms()).map(|i| r.forces[3 * i + k]).sum();
        assert!(total.abs() < 1e-8, "net force axis {k}: {total}");
    }
    // mixed species genuinely differ: W and Be sites see different energies
    let e_w = r.ei[0];
    let e_be = r.ei[1];
    assert!((e_w - e_be).abs() > 1e-12, "species are indistinguishable: {e_w}");

    // rigid translation + wrap: identical physics
    let mut s2 = s.clone();
    for i in 0..s2.natoms() {
        s2.pos[3 * i] += 1.7;
        s2.pos[3 * i + 1] -= 0.9;
        s2.pos[3 * i + 2] += 2.3;
    }
    s2.wrap_all();
    let nl2 = NeighborList::build_cells(&s2, cutoff);
    let mut ff2 = ForceField::new(build_field(), 16, nl2.max_count().max(1));
    let r2 = ff2.compute(&s2, &nl2).unwrap();
    for i in 0..s.natoms() {
        assert!(
            (r.ei[i] - r2.ei[i]).abs() < 1e-9 * (1.0 + r.ei[i].abs()),
            "atom {i}: ei {} vs translated {}",
            r.ei[i],
            r2.ei[i]
        );
        for k in 0..3 {
            let (a, b) = (r.forces[3 * i + k], r2.forces[3 * i + k]);
            assert!(
                (a - b).abs() < 1e-8 * (1.0 + a.abs()),
                "atom {i} axis {k}: force {a} vs translated {b}"
            );
        }
    }
}

/// Golden fixture: the committed 2-element `.snapcoeff`/`.snapparam` pair
/// parses to the expected tables and block counts, and a parsed fixture
/// drives a real mixed-species engine.
#[test]
fn wbe_fixture_parses_and_drives_an_engine() {
    let params = SnapCoeffs::parse_snapparam(WBE_PARAM).unwrap();
    assert_eq!(params.twojmax, 2);
    assert!((params.rcutfac - 4.73442).abs() < 1e-12);
    let coeffs = SnapCoeffs::parse_snapcoeff(WBE_COEFF, params).unwrap();
    assert_eq!(coeffs.nelems(), 2);
    assert_eq!(coeffs.elements.symbols, vec!["W", "Be"]);
    assert_eq!(coeffs.elements.radii, vec![0.5, 0.417932]);
    assert_eq!(coeffs.elements.weights, vec![1.0, 0.959049]);
    assert_eq!(coeffs.coeff0, vec![0.0, 0.05]);
    // 5 bispectrum components per element at 2J=2
    let idx = SnapIndex::new(params.twojmax);
    assert_eq!(coeffs.ncoeff_per_elem(), idx.idxb_max);
    assert_eq!(coeffs.beta.len(), 2 * idx.idxb_max);
    assert_eq!(coeffs.beta_block(0), &[0.1, -0.05, 0.02, 0.01, -0.005]);
    assert_eq!(coeffs.beta_block(1), &[-0.08, 0.03, 0.015, -0.01, 0.002]);
    // round-trip through the serializer
    let back = SnapCoeffs::parse_snapcoeff(&coeffs.to_snapcoeff(), params).unwrap();
    assert_eq!(back.elements, coeffs.elements);
    assert_eq!(back.beta, coeffs.beta);
    // and the parsed fixture actually computes
    let mut eng = Variant::Fused.build_multi(
        params,
        Arc::new(idx),
        coeffs.beta.clone(),
        coeffs.elements.clone(),
    );
    let tile = TypedTile::random(53, 3, 4, 2);
    let out = eng.compute(&tile.typed());
    assert!(out.ei.iter().all(|e| e.is_finite()));
    assert!(out.dedr.iter().all(|d| d.is_finite()));
}

/// Fixture rejection paths: short blocks, trailing garbage and malformed
/// element lines fail with messages that name the offender.
#[test]
fn wbe_fixture_mutations_are_rejected_with_useful_errors() {
    let params = SnapCoeffs::parse_snapparam(WBE_PARAM).unwrap();
    // drop the last coefficient: the Be block comes up short
    let mut lines: Vec<&str> = WBE_COEFF.trim_end().lines().collect();
    lines.pop();
    let short = lines.join("\n");
    let err = format!("{:#}", SnapCoeffs::parse_snapcoeff(&short, params).unwrap_err());
    assert!(err.contains("Be"), "{err}");
    assert!(err.contains("expected 6 coefficients"), "{err}");
    // append garbage after the declared blocks
    let trailing = format!("{WBE_COEFF}0.123\n");
    let err = format!("{:#}", SnapCoeffs::parse_snapcoeff(&trailing, params).unwrap_err());
    assert!(err.contains("trailing garbage"), "{err}");
    // unknown snapparam keys are hard errors listing the valid keys
    let err = format!(
        "{:#}",
        SnapCoeffs::parse_snapparam(&format!("{WBE_PARAM}cutoff 3.0\n")).unwrap_err()
    );
    assert!(err.contains("cutoff"), "{err}");
    assert!(err.contains("rcutfac") && err.contains("twojmax"), "{err}");
    // a typed engine rejects out-of-range types with a BadShape error
    let coeffs = SnapCoeffs::parse_snapcoeff(WBE_COEFF, params).unwrap();
    let mut eng = Variant::Fused.build_multi(
        params,
        Arc::new(SnapIndex::new(params.twojmax)),
        coeffs.beta.clone(),
        coeffs.elements.clone(),
    );
    let mut tile = TypedTile::random(59, 2, 3, 2);
    tile.jelems[1] = 7; // only elements 0/1 exist
    let mut out = repro::snap::TileOutput::default();
    let err = eng.compute_into(&tile.typed(), &mut out).unwrap_err();
    assert!(
        matches!(err, repro::snap::EngineError::BadShape(_)),
        "{err:?}"
    );
    assert!(err.to_string().contains("out of range"), "{err}");
    // the engine stays usable afterwards
    tile.jelems[1] = 1;
    eng.compute_into(&tile.typed(), &mut out).unwrap();
    assert!(out.ei.iter().all(|e| e.is_finite()));
}

/// The ElementTable is what makes mixed pairs physically different:
/// shrinking Be's radius far enough switches the W–Be pair off entirely
/// while W–W keeps its legacy cutoff.
#[test]
fn per_pair_cutoffs_actually_gate_mixed_pairs() {
    let twojmax = 2usize;
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = wbe_coeffs(twojmax);
    // one W central atom with one neighbor at r = 3.0 A; W-W cutoff is
    // 4.73 A (in range), but with a tiny fictitious second-element radius
    // the W-X pair cutoff drops below r
    let rij = vec![3.0, 0.0, 0.0];
    let mask = vec![1.0];
    let ielems = vec![0i32];
    let for_jelem = |jelem: i32, elements: ElementTable| {
        let mut eng = Variant::Fused.build_multi(
            params,
            idx.clone(),
            coeffs.beta.clone(),
            elements,
        );
        let jelems = vec![jelem];
        let t = TileInput {
            num_atoms: 1,
            num_nbor: 1,
            rij: &rij,
            mask: &mask,
            elems: Some(TileElems { ielems: &ielems, jelems: &jelems }),
        };
        eng.compute(&t)
    };
    let tiny = ElementTable::new(
        vec!["W".into(), "X".into()],
        vec![0.5, 0.05], // W-X cutoff = 4.73442 * 0.55 = 2.60 A < 3.0 A
        vec![1.0, 1.0],
    )
    .unwrap();
    let in_range = for_jelem(0, tiny.clone());
    let gated = for_jelem(1, tiny);
    assert!(in_range.ei[0].abs() > 1e-12, "W-W pair must contribute");
    // outside its pair cutoff the neighbor is invisible: the energy is the
    // isolated-atom (wself-only) value and dedr vanishes
    assert!(gated.dedr.iter().all(|&d| d == 0.0), "gated pair must not pull");
    assert!((gated.ei[0] - in_range.ei[0]).abs() > 1e-12);
}
