//! Descriptor-serving conformance suite: the bispectrum-extraction path
//! (`compute_descriptors_into`) must produce fitting-grade B_k / dB_k/dr.
//!
//! What "fitting-grade" pins down:
//! * dB_k/dr is the true derivative of B_k (central finite differences);
//! * the beta contraction of dB_k/dr *is* the force path's `dedr` — bitwise
//!   on the baseline engine, 1e-8 against the adjoint force formulation;
//! * baseline and adjoint descriptors agree bitwise (two formulations, one
//!   answer), serial and sharded agree bitwise, and typed multi-element
//!   tiles flow through;
//! * B_k is rotation-invariant and permutation-consistent;
//! * engines that never materialize B_k (fused / Euler-identity) refuse
//!   with a structured `Backend` error and the serving pipeline survives;
//! * the JSON verb and the binary 0x04/0x84 frames return bit-identical
//!   payloads, and quadratic-SNAP energies/forces built from descriptors
//!   match finite differences.

use repro::config::EngineSpec;
use repro::snap::coeff::SnapCoeffs;
use repro::snap::engine::{EngineError, ForceEngine, TileElems, TileInput};
use repro::snap::sharded::ShardedEngine;
use repro::snap::{DescriptorOutput, EngineFactory, SnapIndex};
use repro::util::json::Json;
use repro::util::XorShift;

/// Deterministic padded tile: `na x nn` slots, ~1/4 masked out.
struct Tile {
    na: usize,
    nn: usize,
    rij: Vec<f64>,
    mask: Vec<f64>,
}

impl Tile {
    fn random(seed: u64, na: usize, nn: usize) -> Tile {
        let mut rng = XorShift::new(seed);
        let mut rij = Vec::new();
        let mut mask = Vec::new();
        for _ in 0..na * nn {
            loop {
                let v = [
                    rng.uniform(-2.4, 2.4),
                    rng.uniform(-2.4, 2.4),
                    rng.uniform(-2.4, 2.4),
                ];
                if (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt() > 0.8 {
                    rij.extend_from_slice(&v);
                    break;
                }
            }
            mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
        }
        Tile { na, nn, rij, mask }
    }

    fn input(&self) -> TileInput<'_> {
        TileInput {
            num_atoms: self.na,
            num_nbor: self.nn,
            rij: &self.rij,
            mask: &self.mask,
            elems: None,
        }
    }
}

fn factory(engine: &str, twojmax: usize) -> EngineFactory {
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    EngineSpec::new(twojmax)
        .engine(engine)
        .beta(coeffs.beta)
        .build_factory()
        .unwrap()
        .factory
}

fn descriptors(engine: &str, twojmax: usize, input: &TileInput, gradients: bool) -> DescriptorOutput {
    let mut eng = (factory(engine, twojmax))().unwrap();
    let mut out = DescriptorOutput::default();
    eng.compute_descriptors_into(input, gradients, &mut out).unwrap();
    out
}

#[test]
fn gradients_are_finite_differences_of_blist() {
    let twojmax = 2;
    let tile = Tile::random(7, 2, 4);
    let desc = descriptors("baseline", twojmax, &tile.input(), true);
    let h = 1e-5;
    for atom in 0..tile.na {
        for nbor in 0..tile.nn {
            if tile.mask[atom * tile.nn + nbor] == 0.0 {
                continue;
            }
            for k in 0..3 {
                let o = (atom * tile.nn + nbor) * 3 + k;
                let mut plus = tile.rij.clone();
                let mut minus = tile.rij.clone();
                plus[o] += h;
                minus[o] -= h;
                let bp = descriptors(
                    "baseline",
                    twojmax,
                    &TileInput {
                        num_atoms: tile.na,
                        num_nbor: tile.nn,
                        rij: &plus,
                        mask: &tile.mask,
                        elems: None,
                    },
                    false,
                );
                let bm = descriptors(
                    "baseline",
                    twojmax,
                    &TileInput {
                        num_atoms: tile.na,
                        num_nbor: tile.nn,
                        rij: &minus,
                        mask: &tile.mask,
                        elems: None,
                    },
                    false,
                );
                let row = desc.dblist_row(atom, nbor);
                for l in 0..desc.num_bispectrum {
                    let fd = (bp.blist_row(atom)[l] - bm.blist_row(atom)[l]) / (2.0 * h);
                    let db = row[l * 3 + k];
                    let scale = 1.0f64.max(fd.abs()).max(db.abs());
                    assert!(
                        (fd - db).abs() <= 1e-6 * scale,
                        "atom {atom} nbor {nbor} B_{l} d{k}: fd={fd} vs analytic={db}"
                    );
                }
            }
        }
    }
}

#[test]
fn beta_contraction_of_gradients_reproduces_dedr() {
    let twojmax = 3;
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    let tile = Tile::random(11, 5, 6);
    let desc = descriptors("baseline", twojmax, &tile.input(), true);

    // bitwise against the baseline force path: same kernels, same order
    let mut eng = (factory("baseline", twojmax))().unwrap();
    let forces = eng.compute(&tile.input());
    for atom in 0..tile.na {
        for nbor in 0..tile.nn {
            let row = desc.dblist_row(atom, nbor);
            for k in 0..3 {
                let contracted: f64 = (0..desc.num_bispectrum)
                    .map(|l| coeffs.beta[l] * row[l * 3 + k])
                    .sum();
                let dedr = forces.dedr[(atom * tile.nn + nbor) * 3 + k];
                assert_eq!(
                    contracted.to_bits(),
                    dedr.to_bits(),
                    "baseline contraction diverged at atom {atom} nbor {nbor} k {k}"
                );
            }
        }
    }

    // the adjoint force formulation computes dedr through Y_jk instead of
    // dB_k — an independent derivation the contraction must match to 1e-8
    let mut adj = (factory("pre-adjoint-pair", twojmax))().unwrap();
    let adj_forces = adj.compute(&tile.input());
    for (i, (&a, &b)) in forces.dedr.iter().zip(adj_forces.dedr.iter()).enumerate() {
        assert!((a - b).abs() <= 1e-8 * 1.0f64.max(a.abs()), "dedr[{i}]: {a} vs {b}");
    }
}

#[test]
fn baseline_and_adjoint_descriptors_agree_bitwise() {
    let twojmax = 3;
    let tile = Tile::random(19, 4, 5);
    let base = descriptors("baseline", twojmax, &tile.input(), true);
    let adj = descriptors("pre-adjoint-pair", twojmax, &tile.input(), true);
    assert_eq!(base.num_bispectrum, adj.num_bispectrum);
    for (i, (a, b)) in base.blist.iter().zip(adj.blist.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "blist[{i}]: {a} vs {b}");
    }
    for (i, (a, b)) in base.dblist.iter().zip(adj.dblist.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "dblist[{i}]: {a} vs {b}");
    }
}

#[test]
fn blist_is_rotation_invariant() {
    let twojmax = 2;
    let tile = Tile::random(23, 3, 5);
    let want = descriptors("baseline", twojmax, &tile.input(), false);
    // Rz(0.7) * Rx(0.4) applied to every displacement
    let (ca, sa) = (0.7f64.cos(), 0.7f64.sin());
    let (cb, sb) = (0.4f64.cos(), 0.4f64.sin());
    let mut rot = tile.rij.clone();
    for p in rot.chunks_exact_mut(3) {
        let (x, y, z) = (p[0], p[1], p[2]);
        // Rx
        let (y, z) = (cb * y - sb * z, sb * y + cb * z);
        // Rz
        p[0] = ca * x - sa * y;
        p[1] = sa * x + ca * y;
        p[2] = z;
    }
    let got = descriptors(
        "baseline",
        twojmax,
        &TileInput {
            num_atoms: tile.na,
            num_nbor: tile.nn,
            rij: &rot,
            mask: &tile.mask,
            elems: None,
        },
        false,
    );
    for (i, (a, b)) in want.blist.iter().zip(got.blist.iter()).enumerate() {
        let scale = 1.0f64.max(a.abs());
        assert!((a - b).abs() <= 1e-10 * scale, "blist[{i}]: {a} vs rotated {b}");
    }
}

#[test]
fn descriptors_are_permutation_consistent() {
    let twojmax = 2;
    let tile = Tile::random(29, 5, 4);
    let want = descriptors("baseline", twojmax, &tile.input(), true);

    // atom permutation: rows travel with their atoms, bitwise
    let perm = [3usize, 0, 4, 1, 2];
    let mut rij = vec![0.0; tile.rij.len()];
    let mut mask = vec![0.0; tile.mask.len()];
    for (dst, &src) in perm.iter().enumerate() {
        rij[dst * tile.nn * 3..(dst + 1) * tile.nn * 3]
            .copy_from_slice(&tile.rij[src * tile.nn * 3..(src + 1) * tile.nn * 3]);
        mask[dst * tile.nn..(dst + 1) * tile.nn]
            .copy_from_slice(&tile.mask[src * tile.nn..(src + 1) * tile.nn]);
    }
    let got = descriptors(
        "baseline",
        twojmax,
        &TileInput { num_atoms: tile.na, num_nbor: tile.nn, rij: &rij, mask: &mask, elems: None },
        true,
    );
    for (dst, &src) in perm.iter().enumerate() {
        assert_eq!(
            got.blist_row(dst),
            want.blist_row(src),
            "atom permutation must move B_k rows bitwise"
        );
        for n in 0..tile.nn {
            assert_eq!(got.dblist_row(dst, n), want.dblist_row(src, n));
        }
    }

    // neighbor-slot reversal: a sum reordering, so equal to tight tolerance
    let mut rij = vec![0.0; tile.rij.len()];
    let mut mask = vec![0.0; tile.mask.len()];
    for a in 0..tile.na {
        for n in 0..tile.nn {
            let rn = tile.nn - 1 - n;
            rij[(a * tile.nn + n) * 3..(a * tile.nn + n) * 3 + 3]
                .copy_from_slice(&tile.rij[(a * tile.nn + rn) * 3..(a * tile.nn + rn) * 3 + 3]);
            mask[a * tile.nn + n] = tile.mask[a * tile.nn + rn];
        }
    }
    let rev = descriptors(
        "baseline",
        twojmax,
        &TileInput { num_atoms: tile.na, num_nbor: tile.nn, rij: &rij, mask: &mask, elems: None },
        false,
    );
    for (i, (a, b)) in want.blist.iter().zip(rev.blist.iter()).enumerate() {
        let scale = 1.0f64.max(a.abs());
        assert!((a - b).abs() <= 1e-12 * scale, "blist[{i}]: {a} vs reversed {b}");
    }
}

#[test]
fn sharded_descriptors_match_serial_bitwise() {
    let twojmax = 2;
    let f = factory("baseline", twojmax);
    let tile = Tile::random(31, 13, 4);
    let mut serial = f().unwrap();
    let mut want = DescriptorOutput::default();
    serial.compute_descriptors_into(&tile.input(), true, &mut want).unwrap();
    for shards in [2, 3, 5] {
        let mut sharded = ShardedEngine::new(&f, shards).unwrap();
        let mut got = DescriptorOutput::default();
        sharded.compute_descriptors_into(&tile.input(), true, &mut got).unwrap();
        assert_eq!(want, got, "shards={shards}");
    }
}

#[test]
fn typed_multi_element_tiles_flow_through() {
    let twojmax = 2;
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic_multi(twojmax, idx.idxb_max, 2, 42);
    let build = |engine: &str| {
        EngineSpec::new(twojmax)
            .engine(engine)
            .beta(coeffs.beta.clone())
            .elements(coeffs.elements.clone())
            .build_factory()
            .unwrap()
            .factory
    };
    let tile = Tile::random(37, 4, 5);
    let ielems: Vec<i32> = (0..tile.na as i32).map(|a| a % 2).collect();
    let jelems: Vec<i32> = (0..(tile.na * tile.nn) as i32).map(|r| (r * 7 + 3) % 2).collect();
    let typed = TileInput {
        num_atoms: tile.na,
        num_nbor: tile.nn,
        rij: &tile.rij,
        mask: &tile.mask,
        elems: Some(TileElems { ielems: &ielems, jelems: &jelems }),
    };
    let mut base = (build("baseline"))().unwrap();
    let mut adj = (build("pre-adjoint-pair"))().unwrap();
    let (mut b_out, mut a_out) = (DescriptorOutput::default(), DescriptorOutput::default());
    base.compute_descriptors_into(&typed, true, &mut b_out).unwrap();
    adj.compute_descriptors_into(&typed, true, &mut a_out).unwrap();
    assert_eq!(b_out, a_out, "typed descriptors must agree bitwise across formulations");
    // the species channel is live: Be weights/cutoffs change the density
    let mut untyped_out = DescriptorOutput::default();
    base.compute_descriptors_into(&tile.input(), false, &mut untyped_out).unwrap();
    assert_ne!(
        b_out.blist, untyped_out.blist,
        "a mixed-species tile must not reproduce the single-element descriptors"
    );
}

#[test]
fn fused_engine_refuses_with_structured_backend_error() {
    let tile = Tile::random(41, 2, 4);
    let mut eng = (factory("fused", 2))().unwrap();
    let mut out = DescriptorOutput::default();
    match eng.compute_descriptors_into(&tile.input(), false, &mut out) {
        Err(EngineError::Backend(msg)) => {
            assert!(msg.contains("does not materialize"), "{msg}");
        }
        other => panic!("expected EngineError::Backend, got {other:?}"),
    }
    // the engine is not poisoned: the force path still serves
    let forces = eng.compute(&tile.input());
    assert!(forces.ei.iter().all(|e| e.is_finite()));
}

mod served {
    use super::*;
    use repro::coordinator::server::{serve_with_stats, shutdown, ServeOptions, ServerStats};
    use repro::coordinator::wire;
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::{SocketAddr, TcpListener, TcpStream};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    struct TestServer {
        addr: SocketAddr,
        stop: Arc<AtomicBool>,
        stats: Arc<ServerStats>,
        handle: std::thread::JoinHandle<std::io::Result<()>>,
    }

    impl TestServer {
        fn start(engine: &str) -> TestServer {
            let opts = ServeOptions {
                workers: 1,
                batch_window: std::time::Duration::ZERO,
                ..ServeOptions::default()
            };
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let stop = Arc::new(AtomicBool::new(false));
            let stats = Arc::new(ServerStats::default());
            let f = factory(engine, 2);
            let (stop2, stats2) = (stop.clone(), stats.clone());
            let handle =
                std::thread::spawn(move || serve_with_stats(listener, f, &opts, stop2, stats2));
            TestServer { addr, stop, stats, handle }
        }

        fn finish(self) {
            shutdown(self.addr, &self.stop);
            self.handle.join().unwrap().unwrap();
        }
    }

    fn json_fmt(v: &[f64]) -> String {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
    }

    #[test]
    fn json_and_binary_descriptor_payloads_are_bit_identical() {
        let srv = TestServer::start("baseline");
        let tile = Tile::random(43, 2, 3);

        // JSON verb
        let conn = TcpStream::connect(srv.addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        writer
            .write_all(
                format!(
                    "{{\"cmd\": \"descriptors\", \"num_atoms\": {}, \"num_nbor\": {}, \
                     \"rij\": [{}], \"mask\": [{}], \"gradients\": true}}\n",
                    tile.na,
                    tile.nn,
                    json_fmt(&tile.rij),
                    json_fmt(&tile.mask)
                )
                .as_bytes(),
            )
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).expect("json reply parses");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{line}");
        let j_blist = j.get("blist").and_then(Json::as_f64_vec).unwrap();
        let j_dblist = j.get("dblist").and_then(Json::as_f64_vec).unwrap();
        drop(reader);
        drop(writer);

        // binary 0x04 -> 0x84 on a fresh connection
        let mut conn = TcpStream::connect(srv.addr).unwrap();
        conn.write_all(&wire::encode_hello(wire::VERSION)).unwrap();
        let mut ack = [0u8; 2];
        conn.read_exact(&mut ack).unwrap();
        assert_eq!(ack, wire::encode_hello_ack());
        conn.write_all(&wire::encode_descriptors(
            tile.na, tile.nn, &tile.rij, &tile.mask, None, true,
        ))
        .unwrap();
        match wire::read_frame(&mut conn).unwrap().unwrap() {
            wire::Frame::DescriptorsResult { num_atoms, num_nbor, blist, dblist, .. } => {
                assert_eq!((num_atoms, num_nbor), (tile.na, tile.nn));
                let dblist = dblist.expect("gradients requested");
                assert_eq!(blist.len(), j_blist.len());
                assert_eq!(dblist.len(), j_dblist.len());
                for (i, (a, b)) in blist.iter().zip(j_blist.iter()).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "blist[{i}]: binary {a} vs json {b}");
                }
                for (i, (a, b)) in dblist.iter().zip(j_dblist.iter()).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "dblist[{i}]: binary {a} vs json {b}");
                }
            }
            other => panic!("expected descriptors result, got {other:?}"),
        }
        drop(conn);
        assert_eq!(srv.stats.descriptor_requests.load(Ordering::Relaxed), 2);
        srv.finish();
    }

    #[test]
    fn fused_server_survives_descriptor_refusal_and_counts_it() {
        let srv = TestServer::start("fused");
        let tile = Tile::random(47, 1, 3);
        let conn = TcpStream::connect(srv.addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        writer
            .write_all(
                format!(
                    "{{\"cmd\": \"descriptors\", \"num_atoms\": 1, \"num_nbor\": {}, \
                     \"rij\": [{}], \"mask\": [{}]}}\n",
                    tile.nn,
                    json_fmt(&tile.rij),
                    json_fmt(&tile.mask)
                )
                .as_bytes(),
            )
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{line}");
        assert_eq!(j.get("code").and_then(Json::as_str), Some("backend"), "{line}");
        // same sole worker keeps serving forces
        writer
            .write_all(
                format!(
                    "{{\"num_atoms\": 1, \"num_nbor\": {}, \"rij\": [{}], \"mask\": [{}]}}\n",
                    tile.nn,
                    json_fmt(&tile.rij),
                    json_fmt(&tile.mask)
                )
                .as_bytes(),
            )
            .unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        assert!(line2.contains("\"ok\": true"), "{line2}");
        drop(reader);
        drop(writer);
        assert_eq!(srv.stats.engine_errors.load(Ordering::Relaxed), 1);
        assert_eq!(srv.stats.descriptor_requests.load(Ordering::Relaxed), 1);
        srv.finish();
    }
}

#[test]
fn quadratic_energy_and_forces_match_finite_differences() {
    // quadratic SNAP through the descriptor path: E_i = beta.B + 1/2 B.A.B,
    // forces = linear contraction at beta_eff = dE/dB.  Checked against
    // central finite differences of the total energy in the pair inputs.
    let twojmax = 2;
    let idx = SnapIndex::new(twojmax);
    let mut coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    let k = coeffs.ncoeff_per_elem();
    let mut rng = XorShift::new(43);
    coeffs.quad = (0..k * (k + 1) / 2).map(|q| 0.01 * rng.normal() / (1.0 + q as f64)).collect();
    coeffs.params.quadraticflag = true;
    assert!(coeffs.quadratic());

    let tile = Tile::random(53, 2, 4);
    let total_energy = |rij: &[f64]| -> f64 {
        let desc = descriptors(
            "baseline",
            twojmax,
            &TileInput {
                num_atoms: tile.na,
                num_nbor: tile.nn,
                rij,
                mask: &tile.mask,
                elems: None,
            },
            false,
        );
        (0..tile.na).map(|a| coeffs.atom_energy(0, desc.blist_row(a))).sum()
    };

    let desc = descriptors("baseline", twojmax, &tile.input(), true);
    let mut beta_eff = Vec::new();
    let h = 1e-5;
    for atom in 0..tile.na {
        coeffs.beta_effective(0, desc.blist_row(atom), &mut beta_eff);
        for nbor in 0..tile.nn {
            if tile.mask[atom * tile.nn + nbor] == 0.0 {
                continue;
            }
            let row = desc.dblist_row(atom, nbor);
            for c in 0..3 {
                let analytic: f64 =
                    (0..desc.num_bispectrum).map(|l| beta_eff[l] * row[l * 3 + c]).sum();
                let o = (atom * tile.nn + nbor) * 3 + c;
                let mut plus = tile.rij.clone();
                let mut minus = tile.rij.clone();
                plus[o] += h;
                minus[o] -= h;
                let fd = (total_energy(&plus) - total_energy(&minus)) / (2.0 * h);
                let scale = 1.0f64.max(fd.abs()).max(analytic.abs());
                assert!(
                    (fd - analytic).abs() <= 1e-6 * scale,
                    "atom {atom} nbor {nbor} c {c}: fd={fd} vs beta_eff.dB={analytic}"
                );
            }
        }
    }
}
