//! Observability contracts, end to end: (1) the kernel profiler is
//! bitwise-invisible — toggling it on or off never changes an engine's
//! outputs, across the whole ladder ∪ fig1 set plus the sharded wrapper
//! and multi-element engines; (2) pipeline traces export as valid Chrome
//! `trace_event` JSON whose spans nest strictly inside their request span
//! with exactly one `compute` span per request; (3) the `metrics` verb
//! round-trips on both wires and its payload parses line-by-line as
//! Prometheus text exposition format.

use repro::config::EngineSpec;
use repro::coordinator::server::{serve_with_stats, shutdown, ServeOptions, ServerStats};
use repro::coordinator::wire;
use repro::snap::coeff::SnapCoeffs;
use repro::snap::engine::{ForceEngine, TileElems, TileInput, TileOutput};
use repro::snap::variants::Variant;
use repro::snap::{SnapIndex, SnapParams};
use repro::util::json::Json;
use repro::util::metrics::TraceSpan;
use repro::util::XorShift;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn random_tile(seed: u64, na: usize, nn: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rng = XorShift::new(seed);
    let mut rij = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..na * nn {
        loop {
            let v = [
                rng.uniform(-2.4, 2.4),
                rng.uniform(-2.4, 2.4),
                rng.uniform(-2.4, 2.4),
            ];
            if (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt() > 0.5 {
                rij.extend_from_slice(&v);
                break;
            }
        }
        mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
    }
    (rij, mask)
}

fn beta_for(twojmax: usize) -> Vec<f64> {
    SnapCoeffs::synthetic(twojmax, SnapIndex::new(twojmax).idxb_max, 42).beta
}

/// Compute the tile with profiling in the requested state and return the
/// outputs; asserts the profile visibility contract for that state.
fn run_once(
    engine: &mut Box<dyn ForceEngine>,
    tile: &TileInput,
    profiled: bool,
    what: &str,
) -> TileOutput {
    engine.set_profiling(profiled);
    let mut out = TileOutput::default();
    engine.compute_into(tile, &mut out).unwrap();
    match engine.kernel_profile() {
        Some(p) => {
            assert!(profiled, "{what}: profile reported while profiling is off");
            assert_eq!(p.dispatches, 1, "{what}: one compute must be one dispatch");
            assert!(p.total_nanos() > 0, "{what}: no time attributed to any stage");
        }
        None => assert!(!profiled, "{what}: no profile reported while profiling is on"),
    }
    out
}

/// (1) Toggling the profiler is invisible in the outputs: off → on → off
/// produces bitwise-identical `ei`/`dedr` for every ladder ∪ fig1 variant
/// and for the sharded wrapper. The off-state engine reports no profile
/// at all (the hot path never touches the clock).
#[test]
fn profiler_toggle_is_bitwise_invisible_ladder_wide() {
    let twojmax = 2usize;
    let beta = beta_for(twojmax);
    let (na, nn) = (6usize, 5usize);
    let (rij, mask) = random_tile(401, na, nn);
    let tile = TileInput { num_atoms: na, num_nbor: nn, rij: &rij, mask: &mask, elems: None };

    for v in Variant::ladder().iter().chain(Variant::fig1()) {
        let label = v.label();
        let mut engine =
            EngineSpec::new(twojmax).variant(*v).beta(beta.clone()).build().unwrap();
        let off = run_once(&mut engine, &tile, false, label);
        let on = run_once(&mut engine, &tile, true, label);
        assert_eq!(off.ei, on.ei, "{label}: profiling changed ei");
        assert_eq!(off.dedr, on.dedr, "{label}: profiling changed dedr");
        let off_again = run_once(&mut engine, &tile, false, label);
        assert_eq!(off.ei, off_again.ei, "{label}: disabling left a residue in ei");
        assert_eq!(off.dedr, off_again.dedr, "{label}: disabling left a residue in dedr");
    }

    // The sharded wrapper: per-shard profiles are drained into the outer
    // aggregate, dispatches count whole tiles, and outputs stay bitwise.
    let mut sharded = EngineSpec::new(twojmax)
        .engine("fused")
        .beta(beta)
        .shards(3)
        .min_atoms_per_shard(1)
        .build()
        .unwrap();
    let off = run_once(&mut sharded, &tile, false, "sharded");
    let on = run_once(&mut sharded, &tile, true, "sharded");
    assert_eq!(off.ei, on.ei, "sharded: profiling changed ei");
    assert_eq!(off.dedr, on.dedr, "sharded: profiling changed dedr");
}

/// (1b) Same invisibility contract for multi-element engines: typed tiles
/// through `build_multi` produce bitwise-identical outputs with the
/// profiler on and off, for the full-kernel variants.
#[test]
fn profiler_toggle_is_bitwise_invisible_multi_element() {
    let twojmax = 2usize;
    let coeffs = SnapCoeffs::synthetic_multi(twojmax, SnapIndex::new(twojmax).idxb_max, 2, 42);
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let (na, nn) = (5usize, 4usize);
    let (rij, mask) = random_tile(402, na, nn);
    let ielems: Vec<i32> = (0..na).map(|a| (a as i32) % 2).collect();
    let jelems: Vec<i32> = (0..na * nn).map(|k| ((k as i32) * 7 + 3) % 2).collect();
    let tile = TileInput {
        num_atoms: na,
        num_nbor: nn,
        rij: &rij,
        mask: &mask,
        elems: Some(TileElems { ielems: &ielems, jelems: &jelems }),
    };

    for v in [Variant::V0Baseline, Variant::V7, Variant::Fused, Variant::FusedSimd] {
        let label = v.label();
        let mut engine: Box<dyn ForceEngine> = v.build_multi(
            params,
            idx.clone(),
            coeffs.beta.clone(),
            coeffs.elements.clone(),
        );
        let off = run_once(&mut engine, &tile, false, label);
        let on = run_once(&mut engine, &tile, true, label);
        assert_eq!(off.ei, on.ei, "{label} multi: profiling changed ei");
        assert_eq!(off.dedr, on.dedr, "{label} multi: profiling changed dedr");
    }
}

// ---------------------------------------------------------------- server

fn factory(engine: &str, twojmax: usize) -> repro::snap::EngineFactory {
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    EngineSpec::new(twojmax)
        .engine(engine)
        .beta(coeffs.beta)
        .build_factory()
        .unwrap()
        .factory
}

struct TestServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    handle: std::thread::JoinHandle<std::io::Result<()>>,
}

impl TestServer {
    fn start(opts: ServeOptions, engine: &str, twojmax: usize) -> Self {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let f = factory(engine, twojmax);
        let (stop2, stats2) = (stop.clone(), stats.clone());
        let opts2 = opts;
        let handle =
            std::thread::spawn(move || serve_with_stats(listener, f, &opts2, stop2, stats2));
        TestServer { addr, stop, stats, handle }
    }

    fn finish(self) {
        shutdown(self.addr, &self.stop);
        self.handle.join().unwrap().unwrap();
    }
}

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let conn = TcpStream::connect(addr).unwrap();
        let writer = conn.try_clone().unwrap();
        Client { writer, reader: BufReader::new(conn) }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    }
}

/// A repro-frame-v1 client (performs the hello handshake on connect).
struct BinClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl BinClient {
    fn connect(addr: SocketAddr) -> BinClient {
        let conn = TcpStream::connect(addr).unwrap();
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);
        writer.write_all(&wire::encode_hello(wire::VERSION)).unwrap();
        let mut ack = [0u8; 2];
        reader.read_exact(&mut ack).unwrap();
        assert_eq!(ack, wire::encode_hello_ack(), "bad hello ack");
        BinClient { writer, reader }
    }

    fn send(&mut self, frame: &[u8]) {
        self.writer.write_all(frame).unwrap();
    }

    fn recv(&mut self) -> wire::Frame {
        wire::read_frame(&mut self.reader).unwrap().unwrap()
    }
}

fn request_line(seed: u64, na: usize, nn: usize) -> String {
    let (rij, mask) = random_tile(seed, na, nn);
    let fmt = |v: &[f64]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
    format!(
        "{{\"num_atoms\": {na}, \"num_nbor\": {nn}, \"rij\": [{}], \"mask\": [{}]}}",
        fmt(&rij),
        fmt(&mask)
    )
}

fn assert_ok(reply: &str) {
    let parsed = Json::parse(reply).expect("reply parses");
    assert_eq!(parsed.get("ok"), Some(&Json::Bool(true)), "compute failed: {reply}");
}

/// (2) With the trace ring enabled, every request leaves one `request`
/// span and exactly one `compute` span on its own track; child spans are
/// disjoint and strictly contained in the request interval; the Chrome
/// export is valid JSON mirroring the ring.
#[test]
fn trace_spans_nest_strictly_and_export_as_chrome_json() {
    let opts = ServeOptions {
        workers: 2,
        batch_window: std::time::Duration::from_micros(200),
        queue_depth: 64,
        max_batch_atoms: 32,
        ..ServeOptions::default()
    };
    let srv = TestServer::start(opts, "fused", 2);
    srv.stats.trace.set_enabled(true);

    let total = 10usize;
    let mut client = Client::connect(srv.addr);
    for k in 0..total {
        assert_ok(&client.roundtrip(&request_line(700 + k as u64, 1 + k % 3, 4)));
    }

    let spans: Vec<TraceSpan> = srv.stats.trace.snapshot();
    let chrome = srv.stats.trace.to_chrome_json();
    srv.finish();

    // Group by track: one request + one compute span per request, all
    // children disjoint and inside the request interval.
    let mut tids: Vec<u64> = spans.iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    assert_eq!(tids.len(), total, "one track per request");
    for tid in tids {
        let mut mine: Vec<&TraceSpan> = spans.iter().filter(|s| s.tid == tid).collect();
        let req = *mine
            .iter()
            .find(|s| s.name == "request")
            .unwrap_or_else(|| panic!("track {tid} has no request span"));
        assert_eq!(
            mine.iter().filter(|s| s.name == "compute").count(),
            1,
            "track {tid}: exactly one compute span per request"
        );
        mine.retain(|s| s.name != "request");
        assert!(!mine.is_empty());
        mine.sort_by_key(|s| s.ts_ns);
        let (lo, hi) = (req.ts_ns, req.ts_ns + req.dur_ns);
        let mut cursor = lo;
        for s in mine {
            assert!(
                s.ts_ns >= cursor,
                "track {tid}: span {} overlaps its predecessor",
                s.name
            );
            assert!(
                s.ts_ns + s.dur_ns <= hi,
                "track {tid}: span {} escapes the request interval",
                s.name
            );
            cursor = s.ts_ns + s.dur_ns;
        }
    }

    // The export is valid JSON with one event per ring span, all complete
    // ("X") events on pid 1.
    let j = Json::parse(&chrome).expect("chrome trace parses");
    let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert_eq!(events.len(), spans.len(), "export drops or invents spans");
    for ev in events {
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(ev.get("pid").and_then(Json::as_usize), Some(1));
        assert!(ev.get("ts").and_then(Json::as_f64).is_some());
        assert!(ev.get("dur").and_then(Json::as_f64).is_some());
        assert!(ev.get("name").and_then(Json::as_str).is_some());
    }
    assert_eq!(j.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
}

/// Line-by-line structural check of the Prometheus text exposition
/// format: comments are `# HELP`/`# TYPE`, samples are
/// `name{labels} value` with a finite numeric value.
fn assert_parses_as_prometheus(text: &str) {
    let mut samples = 0usize;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            assert!(
                rest.starts_with("HELP ") || rest.starts_with("TYPE "),
                "unknown comment form: {line:?}"
            );
            continue;
        }
        let (metric, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("sample without value: {line:?}"));
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("non-numeric value: {line:?}"));
        assert!(v.is_finite(), "non-finite sample: {line:?}");
        let name_end = metric.find('{').unwrap_or(metric.len());
        let name = &metric[..name_end];
        assert!(
            !name.is_empty()
                && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "bad metric name: {line:?}"
        );
        if name_end < metric.len() {
            assert!(metric.ends_with('}'), "unterminated label set: {line:?}");
            for kv in metric[name_end + 1..metric.len() - 1].split(',') {
                let (k, val) = kv
                    .split_once('=')
                    .unwrap_or_else(|| panic!("label without '=': {line:?}"));
                assert!(!k.is_empty(), "empty label name: {line:?}");
                assert!(
                    val.len() >= 2 && val.starts_with('"') && val.ends_with('"'),
                    "unquoted label value: {line:?}"
                );
            }
        }
        samples += 1;
    }
    assert!(samples > 10, "suspiciously few samples ({samples}):\n{text}");
}

/// (3) The `metrics` verb round-trips on both wires, the payload parses
/// as Prometheus text, and metrics requests keep the stats counter
/// invariant (`requests_total = ok + err + stats_requests`) intact.
#[test]
fn metrics_verb_round_trips_both_wires_as_prometheus_text() {
    let opts = ServeOptions { workers: 1, queue_depth: 16, ..ServeOptions::default() };
    let srv = TestServer::start(opts, "fused", 2);

    let mut client = Client::connect(srv.addr);
    for k in 0..3u64 {
        assert_ok(&client.roundtrip(&request_line(800 + k, 2, 4)));
    }

    // JSON wire: {"cmd": "metrics"} -> {"ok": true, "metrics": "..."}.
    let reply = client.roundtrip("{\"cmd\": \"metrics\"}");
    let j = Json::parse(&reply).expect("metrics reply parses");
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{reply}");
    let text = j.get("metrics").and_then(Json::as_str).expect("metrics payload").to_string();
    assert_parses_as_prometheus(&text);
    assert!(text.contains("repro_requests_total"), "missing core counter:\n{text}");
    assert!(text.contains("repro_replies_ok_total 3"), "ok counter wrong:\n{text}");
    assert!(
        text.contains("repro_stage_latency_seconds{stage=\"compute\",quantile=\"0.99\"}"),
        "missing latency summary:\n{text}"
    );
    assert!(text.contains("repro_kernel_profiling_enabled 0"), "profiler gauge:\n{text}");

    // Binary wire: CMD_METRICS -> CMD_METRICS_TEXT with the same registry.
    let mut bc = BinClient::connect(srv.addr);
    bc.send(&wire::encode_metrics_request());
    match bc.recv() {
        wire::Frame::MetricsText(bin_text) => {
            assert_parses_as_prometheus(&bin_text);
            assert!(bin_text.contains("repro_requests_total"));
            assert!(bin_text.contains("repro_kernel_stage_seconds_total{stage=\"geometry\"}"));
        }
        other => panic!("expected MetricsText, got {other:?}"),
    }

    // The invariant holds with metrics verbs in the mix: they count as
    // stats_requests, not as compute replies.
    let reply = client.roundtrip("{\"cmd\": \"stats\"}");
    let j = Json::parse(&reply).expect("stats reply parses");
    let s = j.get("stats").expect("stats object");
    let get = |k: &str| s.get(k).and_then(Json::as_usize).unwrap();
    assert_eq!(get("replies_ok"), 3);
    assert_eq!(get("stats_requests"), 3, "two metrics verbs + one stats verb");
    assert_eq!(
        get("requests_total"),
        get("replies_ok") + get("replies_err") + get("stats_requests"),
        "{reply}"
    );
    srv.finish();
}

/// (3b) With kernel profiling enabled the `stats` verb grows a `kernels`
/// section whose aggregate reflects the dispatched work, and the
/// Prometheus registry flips its gauge and accumulates stage seconds.
#[test]
fn stats_and_metrics_surface_kernel_aggregate_when_profiling() {
    let opts = ServeOptions { workers: 2, queue_depth: 16, ..ServeOptions::default() };
    let srv = TestServer::start(opts, "fused", 2);
    srv.stats.kernels.set_enabled(true);

    let mut client = Client::connect(srv.addr);
    for k in 0..4u64 {
        assert_ok(&client.roundtrip(&request_line(900 + k, 2, 4)));
    }

    // The enabled flag is immediately visible in the stats reply.
    let reply = client.roundtrip("{\"cmd\": \"stats\"}");
    let j = Json::parse(&reply).expect("stats reply parses");
    let kernels = j.get("stats").and_then(|s| s.get("kernels")).expect("kernels section");
    assert_eq!(kernels.get("enabled"), Some(&Json::Bool(true)), "{reply}");
    assert!(kernels.get("profile").is_some(), "{reply}");

    // Workers absorb each engine profile after the job completes; after a
    // clean shutdown every dispatch is accounted for.
    let stats = srv.stats.clone();
    srv.finish();
    let snap = stats.kernels.snapshot();
    assert!(snap.dispatches >= 1, "no kernel dispatches absorbed");
    assert!(snap.total_nanos() > 0, "no stage time absorbed");
    let frac: f64 = snap.fractions().iter().sum();
    assert!((frac - 1.0).abs() < 1e-9, "stage fractions must sum to 1, got {frac}");
    let prom = stats.prometheus_text();
    assert!(prom.contains("repro_kernel_profiling_enabled 1"));
    assert!(prom.contains("repro_kernel_dispatches_total"));
    assert_parses_as_prometheus(&prom);
}
