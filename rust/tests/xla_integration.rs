//! All-layers-compose check: the PJRT-executed Pallas/JAX artifacts must
//! agree with the native Rust engines on identical inputs.
//!
//! L1 (Pallas kernels) -> L2 (JAX model) -> AOT HLO text -> L3 (this crate's
//! runtime) on one side; the hand-written Rust engines (validated against
//! the jnp oracle via goldens) on the other.  Agreement here certifies the
//! whole stack end to end.

use repro::bench::Workload;
use repro::runtime::{Runtime, XlaEngine};
use repro::snap::coeff::SnapCoeffs;
use repro::snap::engine::ForceEngine;
use repro::snap::fused::{FusedConfig, FusedEngine};
use repro::snap::{SnapIndex, SnapParams};
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    artifacts_dir().join(format!("{name}.hlo.txt")).exists()
}

macro_rules! require_artifact {
    ($name:expr) => {
        if !have($name) {
            eprintln!("skipping: artifact {} not built (run `make artifacts`)", $name);
            return;
        }
    };
}

fn compare(artifact: &str, twojmax: usize, cells: usize) {
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 42);
    let w = Workload::tungsten(cells, params.rcut());
    let tile = w.tile();

    let rt = Runtime::open(artifacts_dir()).expect("runtime opens");
    let mut xla = XlaEngine::new(rt, artifact, coeffs.beta.clone()).expect("xla engine");
    let mut native = FusedEngine::new(
        params, idx, coeffs.beta, FusedConfig::default(), "native",
    );

    let got = xla.compute(&tile);
    let want = native.compute(&tile);

    let escale = want.ei.iter().fold(1.0f64, |m, x| m.max(x.abs()));
    for (i, (g, w_)) in got.ei.iter().zip(want.ei.iter()).enumerate() {
        assert!(
            (g - w_).abs() < 1e-8 * escale,
            "{artifact} ei[{i}]: xla {g} vs native {w_}"
        );
    }
    let fscale = want.dedr.iter().fold(1.0f64, |m, x| m.max(x.abs()));
    for (i, (g, w_)) in got.dedr.iter().zip(want.dedr.iter()).enumerate() {
        assert!(
            (g - w_).abs() < 1e-8 * fscale,
            "{artifact} dedr[{i}]: xla {g} vs native {w_}"
        );
    }
}

#[test]
fn pallas_artifact_2j8_matches_native() {
    require_artifact!("snap_2j8");
    // 3^3 bcc cells = 54 atoms -> two 32-atom tiles incl. padding
    compare("snap_2j8", 8, 3);
}

#[test]
fn ref_artifact_2j8_matches_native() {
    require_artifact!("snap_2j8_ref");
    compare("snap_2j8_ref", 8, 3);
}

#[test]
fn pallas_artifact_2j14_matches_native() {
    if std::env::var("REPRO_HEAVY_TESTS").is_err() {
        eprintln!("skipping 2J14 PJRT compile (set REPRO_HEAVY_TESTS=1 to run)");
        return;
    }
    require_artifact!("snap_2j14");
    compare("snap_2j14", 14, 2);
}

#[test]
fn runtime_registry_lists_artifacts() {
    if !artifacts_dir().join("snap_2j8.meta.json").exists() {
        eprintln!("skipping (artifacts not built)");
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    assert!(rt.names().contains(&"snap_2j8"));
    let meta = rt.meta("snap_2j8").unwrap();
    assert_eq!(meta.twojmax, 8);
    assert_eq!(meta.num_bispectrum, 55);
}

#[test]
fn xla_engine_handles_multiple_tiles_and_padding() {
    require_artifact!("snap_2j8");
    let params = SnapParams::with_twojmax(8);
    let idx = Arc::new(SnapIndex::new(8));
    let coeffs = SnapCoeffs::synthetic(8, idx.idxb_max, 7);
    // 3^3 cells = 54 atoms: one full 32-atom tile + a 22-atom tile with
    // 10 fully padded fake rows
    let w = Workload::tungsten(3, params.rcut());
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let mut xla = XlaEngine::new(rt, "snap_2j8", coeffs.beta.clone()).unwrap();
    let mut native = FusedEngine::new(
        params, idx, coeffs.beta, FusedConfig::default(), "native",
    );
    let got = xla.compute(&w.tile());
    let want = native.compute(&w.tile());
    assert_eq!(got.ei.len(), 54);
    let fscale = want.dedr.iter().fold(1.0f64, |m, x| m.max(x.abs()));
    for (g, w_) in got.dedr.iter().zip(want.dedr.iter()) {
        assert!((g - w_).abs() < 1e-8 * fscale);
    }
}
