//! Property-based tests (proptest-style, hand-rolled generator loop: the
//! offline build has no proptest crate).  Each property runs across a sweep
//! of seeded random cases; failures print the offending seed for replay.
//!
//! Coordinator invariants: batching/tiling never changes physics, padding
//! is inert, routing to any engine yields identical results, global force
//! balance holds on random (not just lattice) geometry.

use repro::coordinator::ForceField;
use repro::md::boxpbc::SimBox;
use repro::md::{NeighborList, Structure};
use repro::snap::coeff::SnapCoeffs;
use repro::snap::engine::{ForceEngine, TileInput};
use repro::snap::variants::Variant;
use repro::snap::{SnapIndex, SnapParams};
use repro::util::XorShift;
use std::sync::Arc;

const CASES: u64 = 12;

fn random_structure(rng: &mut XorShift) -> Structure {
    let n = 8 + rng.below(40);
    let l = 9.0 + rng.next_f64() * 6.0;
    let pos: Vec<f64> = (0..3 * n).map(|_| rng.uniform(0.0, l)).collect();
    Structure::new(SimBox::cubic(l), pos, 183.84)
}

fn random_tile(rng: &mut XorShift, p: &SnapParams, na: usize, nn: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rij = Vec::with_capacity(na * nn * 3);
    let mut mask = Vec::with_capacity(na * nn);
    for _ in 0..na * nn {
        // keep radii in a well-conditioned band
        loop {
            let v = [
                rng.uniform(-0.6, 0.6) * p.rcut(),
                rng.uniform(-0.6, 0.6) * p.rcut(),
                rng.uniform(-0.6, 0.6) * p.rcut(),
            ];
            let r = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            if r > 0.2 {
                rij.extend_from_slice(&v);
                break;
            }
        }
        mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
    }
    (rij, mask)
}

fn engine(v: Variant, twojmax: usize, seed: u64) -> Box<dyn ForceEngine> {
    let params = SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let beta = SnapCoeffs::synthetic(twojmax, idx.idxb_max, seed).beta;
    v.build(params, idx, beta)
}

#[test]
fn prop_tiling_is_invisible() {
    for seed in 0..CASES {
        let mut rng = XorShift::new(1000 + seed);
        let s = random_structure(&mut rng);
        let nl = NeighborList::build_cells(&s, 4.0);
        let nn = nl.max_count().max(1);
        let run = |tile: usize| {
            let mut ff = ForceField::new(engine(Variant::Fused, 3, 42), tile, nn);
            ff.compute(&s, &nl).unwrap()
        };
        let a = run(1);
        let b = run(7);
        let c = run(1024);
        for i in 0..a.forces.len() {
            assert!(
                (a.forces[i] - b.forces[i]).abs() < 1e-10,
                "seed {seed} tile 1 vs 7 at {i}"
            );
            assert!((a.forces[i] - c.forces[i]).abs() < 1e-10, "seed {seed}");
        }
        assert!((a.e_pot() - b.e_pot()).abs() < 1e-10);
    }
}

#[test]
fn prop_engines_agree_on_random_geometry() {
    for seed in 0..CASES {
        let mut rng = XorShift::new(2000 + seed);
        let p = SnapParams::with_twojmax(3);
        let (rij, mask) = random_tile(&mut rng, &p, 3, 7);
        let inp = TileInput { num_atoms: 3, num_nbor: 7, rij: &rij, mask: &mask, elems: None };
        let mut base = engine(Variant::V0Baseline, 3, 42);
        let want = base.compute(&inp);
        for v in [Variant::V2, Variant::V4, Variant::V6, Variant::Fused, Variant::FusedAosoa] {
            let mut e = engine(v, 3, 42);
            let got = e.compute(&inp);
            let scale = want.dedr.iter().fold(1.0f64, |m, x| m.max(x.abs()));
            for i in 0..want.dedr.len() {
                assert!(
                    (want.dedr[i] - got.dedr[i]).abs() < 1e-9 * scale,
                    "seed {seed} {v:?} dedr[{i}]"
                );
            }
        }
    }
}

#[test]
fn prop_padding_rows_are_inert() {
    for seed in 0..CASES {
        let mut rng = XorShift::new(3000 + seed);
        let p = SnapParams::with_twojmax(3);
        let (rij, mask) = random_tile(&mut rng, &p, 2, 5);
        let inp = TileInput { num_atoms: 2, num_nbor: 5, rij: &rij, mask: &mask, elems: None };
        let mut e = engine(Variant::Fused, 3, 42);
        let want = e.compute(&inp);
        // append 3 garbage masked lanes per atom
        let mut rij2 = Vec::new();
        let mut mask2 = Vec::new();
        for a in 0..2 {
            rij2.extend_from_slice(&rij[a * 5 * 3..(a + 1) * 5 * 3]);
            for _ in 0..3 {
                rij2.extend_from_slice(&[rng.normal(), rng.normal(), rng.normal()]);
            }
            mask2.extend_from_slice(&mask[a * 5..(a + 1) * 5]);
            mask2.extend_from_slice(&[0.0, 0.0, 0.0]);
        }
        let inp2 = TileInput { num_atoms: 2, num_nbor: 8, rij: &rij2, mask: &mask2, elems: None };
        let got = e.compute(&inp2);
        for a in 0..2 {
            assert!((want.ei[a] - got.ei[a]).abs() < 1e-10, "seed {seed}");
            for n in 0..5 {
                for k in 0..3 {
                    let i1 = (a * 5 + n) * 3 + k;
                    let i2 = (a * 8 + n) * 3 + k;
                    assert!(
                        (want.dedr[i1] - got.dedr[i2]).abs() < 1e-10,
                        "seed {seed} pair ({a},{n})"
                    );
                }
            }
            for n in 5..8 {
                for k in 0..3 {
                    assert_eq!(got.dedr[(a * 8 + n) * 3 + k], 0.0);
                }
            }
        }
    }
}

#[test]
fn prop_rotation_invariance_of_energy() {
    for seed in 0..CASES {
        let mut rng = XorShift::new(4000 + seed);
        let p = SnapParams::with_twojmax(4);
        let (rij, mask) = random_tile(&mut rng, &p, 2, 6);
        // random rotation (axis-angle)
        let axis = {
            let v = [rng.normal(), rng.normal(), rng.normal()];
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            [v[0] / n, v[1] / n, v[2] / n]
        };
        let ang = rng.uniform(0.3, 2.8);
        let (c, s) = (ang.cos(), ang.sin());
        let rot = |v: [f64; 3]| -> [f64; 3] {
            // Rodrigues
            let dot = axis[0] * v[0] + axis[1] * v[1] + axis[2] * v[2];
            let cross = [
                axis[1] * v[2] - axis[2] * v[1],
                axis[2] * v[0] - axis[0] * v[2],
                axis[0] * v[1] - axis[1] * v[0],
            ];
            [
                v[0] * c + cross[0] * s + axis[0] * dot * (1.0 - c),
                v[1] * c + cross[1] * s + axis[1] * dot * (1.0 - c),
                v[2] * c + cross[2] * s + axis[2] * dot * (1.0 - c),
            ]
        };
        let mut rij_rot = vec![0.0; rij.len()];
        for i in 0..rij.len() / 3 {
            let v = rot([rij[3 * i], rij[3 * i + 1], rij[3 * i + 2]]);
            rij_rot[3 * i..3 * i + 3].copy_from_slice(&v);
        }
        let mut e = engine(Variant::Fused, 4, 42);
        let a = e.compute(&TileInput {
            num_atoms: 2,
            num_nbor: 6,
            rij: &rij,
            mask: &mask,
            elems: None,
        });
        let b = e.compute(&TileInput {
            num_atoms: 2,
            num_nbor: 6,
            rij: &rij_rot,
            mask: &mask,
            elems: None,
        });
        for (x, y) in a.ei.iter().zip(b.ei.iter()) {
            assert!(
                (x - y).abs() < 1e-8 * (1.0 + x.abs()),
                "seed {seed}: E {x} vs rotated {y}"
            );
        }
    }
}

#[test]
fn prop_force_balance_on_random_structures() {
    for seed in 0..CASES {
        let mut rng = XorShift::new(5000 + seed);
        let s = random_structure(&mut rng);
        let nl = NeighborList::build_cells(&s, 4.2);
        let mut ff =
            ForceField::new(engine(Variant::Fused, 2, 42), 16, nl.max_count().max(1));
        let r = ff.compute(&s, &nl).unwrap();
        for k in 0..3 {
            let sum: f64 = (0..s.natoms()).map(|i| r.forces[3 * i + k]).sum();
            assert!(sum.abs() < 1e-8, "seed {seed} axis {k}: net force {sum}");
        }
        assert!(r.forces.iter().all(|f| f.is_finite()));
        assert!(r.virial.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn prop_energy_extensive_under_duplication() {
    // two disjoint copies of the same neighborhood = twice the energy
    for seed in 0..CASES {
        let mut rng = XorShift::new(6000 + seed);
        let p = SnapParams::with_twojmax(3);
        let (rij, mask) = random_tile(&mut rng, &p, 1, 6);
        let mut e = engine(Variant::Fused, 3, 42);
        let single = e.compute(&TileInput {
            num_atoms: 1,
            num_nbor: 6,
            rij: &rij,
            mask: &mask,
            elems: None,
        });
        let mut rij2 = rij.clone();
        rij2.extend_from_slice(&rij);
        let mut mask2 = mask.clone();
        mask2.extend_from_slice(&mask);
        let double = e.compute(&TileInput {
            num_atoms: 2,
            num_nbor: 6,
            rij: &rij2,
            mask: &mask2,
            elems: None,
        });
        let want = 2.0 * single.ei[0];
        let got = double.ei[0] + double.ei[1];
        assert!((want - got).abs() < 1e-10 * (1.0 + want.abs()), "seed {seed}");
    }
}
