//! Cross-language golden tests: the Rust engines and index machinery must
//! reproduce the jnp oracle's numbers exactly (artifacts/golden/*.json,
//! written by `python -m compile.aot`).
//!
//! This is the strongest correctness anchor in the repo: the Python oracle
//! is pinned by autodiff + rotation invariance, and these tests transfer
//! that trust to every native engine.

use repro::snap::baseline::{BaselineEngine, Staging};
use repro::snap::engine::{ForceEngine, TileInput};
use repro::snap::fused::{FusedConfig, FusedEngine};
use repro::snap::kernels;
use repro::snap::variants::Variant;
use repro::snap::{SnapIndex, SnapParams};
use repro::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden")
}

fn load(name: &str) -> Option<Json> {
    let path = golden_dir().join(name);
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("golden json parses"))
}

macro_rules! require_golden {
    ($name:expr) => {
        match load($name) {
            Some(j) => j,
            None => {
                eprintln!("skipping: {} not built (run `make artifacts`)", $name);
                return;
            }
        }
    };
}

fn vecf(j: &Json, key: &str) -> Vec<f64> {
    j.get(key).and_then(Json::as_f64_vec).unwrap_or_else(|| panic!("missing {key}"))
}

#[test]
fn index_machinery_matches_python() {
    for tjm in [2usize, 4, 8, 14] {
        let g = match load(&format!("index_2j{tjm}.json")) {
            Some(g) => g,
            None => {
                eprintln!("skipping index_2j{tjm} (artifacts not built)");
                return;
            }
        };
        let idx = SnapIndex::new(tjm);
        assert_eq!(idx.idxu_max, g.get("idxu_max").unwrap().as_usize().unwrap());
        assert_eq!(idx.idxb_max, g.get("idxb_max").unwrap().as_usize().unwrap());
        assert_eq!(idx.idxz_max, g.get("idxz_max").unwrap().as_usize().unwrap());
        assert_eq!(
            idx.zplan_seg.len(),
            g.get("zplan_rows").unwrap().as_usize().unwrap()
        );
        // value-level checks
        let cg_head = vecf(&g, "cglist_head");
        for (i, want) in cg_head.iter().enumerate() {
            assert!(
                (idx.cglist[i] - want).abs() < 1e-12,
                "2J={tjm} cglist[{i}]: {} vs {want}",
                idx.cglist[i]
            );
        }
        let cg_sum: f64 = idx.cglist.iter().map(|c| c.abs()).sum();
        let want_sum = g.get("cglist_sum").unwrap().as_f64().unwrap();
        assert!((cg_sum - want_sum).abs() < 1e-9 * want_sum.max(1.0));
        let zc_sum: f64 = idx.zplan_c.iter().map(|c| c.abs()).sum();
        let want_zc = g.get("zplan_c_sum").unwrap().as_f64().unwrap();
        assert!((zc_sum - want_zc).abs() < 1e-9 * want_zc.max(1.0));
        let yfac_sum: f64 = idx.yplan_fac.iter().sum();
        assert!(
            (yfac_sum - g.get("yplan_fac_sum").unwrap().as_f64().unwrap()).abs() < 1e-9
        );
        let w_sum: f64 = idx.dedr_w.iter().sum();
        assert!((w_sum - g.get("dedr_w_sum").unwrap().as_f64().unwrap()).abs() < 1e-9);
        // idxb triple-for-triple
        let idxb_flat = vecf(&g, "idxb");
        for (i, &(j1, j2, j)) in idx.idxb.iter().enumerate() {
            assert_eq!(idxb_flat[3 * i] as usize, j1);
            assert_eq!(idxb_flat[3 * i + 1] as usize, j2);
            assert_eq!(idxb_flat[3 * i + 2] as usize, j);
        }
    }
}

struct Case {
    twojmax: usize,
    na: usize,
    nn: usize,
    rij: Vec<f64>,
    mask: Vec<f64>,
    beta: Vec<f64>,
    ulisttot_re: Vec<f64>,
    ulisttot_im: Vec<f64>,
    ylist_re: Vec<f64>,
    ylist_im: Vec<f64>,
    blist: Vec<f64>,
    ei: Vec<f64>,
    dedr: Vec<f64>,
}

fn parse_case(j: &Json) -> Case {
    Case {
        twojmax: j.get("twojmax").unwrap().as_usize().unwrap(),
        na: j.get("num_atoms").unwrap().as_usize().unwrap(),
        nn: j.get("num_nbor").unwrap().as_usize().unwrap(),
        rij: vecf(j, "rij"),
        mask: vecf(j, "mask"),
        beta: vecf(j, "beta"),
        ulisttot_re: vecf(j, "ulisttot_re"),
        ulisttot_im: vecf(j, "ulisttot_im"),
        ylist_re: vecf(j, "ylist_re"),
        ylist_im: vecf(j, "ylist_im"),
        blist: vecf(j, "blist"),
        ei: vecf(j, "ei"),
        dedr: vecf(j, "dedr"),
    }
}

fn check_case(c: &Case) {
    let params = SnapParams::with_twojmax(c.twojmax);
    let idx = Arc::new(SnapIndex::new(c.twojmax));
    let iu = idx.idxu_max;

    // --- stage-level: ulisttot / ylist / blist via the kernel helpers ---
    let mut sr = vec![0.0; iu];
    let mut si = vec![0.0; iu];
    let mut ut_r = vec![0.0; iu];
    let mut ut_i = vec![0.0; iu];
    let mut y_r = vec![0.0; iu];
    let mut y_i = vec![0.0; iu];
    let mut z_r = vec![0.0; idx.idxz_max];
    let mut z_i = vec![0.0; idx.idxz_max];
    let mut blist = vec![0.0; idx.idxb_max];
    for atom in 0..c.na {
        let rows = (0..c.nn).map(|n| {
            let o = (atom * c.nn + n) * 3;
            (
                [c.rij[o], c.rij[o + 1], c.rij[o + 2]],
                c.mask[atom * c.nn + n] > 0.5,
            )
        });
        kernels::compute_utot_atom(
            &idx, &params, rows, &mut sr, &mut si, &mut ut_r, &mut ut_i,
        );
        for jju in 0..iu {
            let o = atom * iu + jju;
            assert!(
                (ut_r[jju] - c.ulisttot_re[o]).abs() < 1e-10,
                "2J={} atom {atom} utot_re[{jju}]: {} vs {}",
                c.twojmax,
                ut_r[jju],
                c.ulisttot_re[o]
            );
            assert!((ut_i[jju] - c.ulisttot_im[o]).abs() < 1e-10);
        }
        kernels::compute_ylist(&idx, &ut_r, &ut_i, &c.beta, &mut y_r, &mut y_i);
        for jju in 0..iu {
            let o = atom * iu + jju;
            assert!(
                (y_r[jju] - c.ylist_re[o]).abs() < 1e-9,
                "2J={} atom {atom} y_re[{jju}]: {} vs {}",
                c.twojmax,
                y_r[jju],
                c.ylist_re[o]
            );
            assert!((y_i[jju] - c.ylist_im[o]).abs() < 1e-9);
        }
        kernels::compute_zlist(&idx, &ut_r, &ut_i, &mut z_r, &mut z_i);
        kernels::compute_blist(&idx, &ut_r, &ut_i, &z_r, &z_i, &mut blist);
        for l in 0..idx.idxb_max {
            let o = atom * idx.idxb_max + l;
            assert!(
                (blist[l] - c.blist[o]).abs() < 1e-9 * (1.0 + c.blist[o].abs()),
                "2J={} atom {atom} B[{l}]: {} vs {}",
                c.twojmax,
                blist[l],
                c.blist[o]
            );
        }
    }

    // --- engine-level: ei + dedr through the public ForceEngine API ---
    let input =
        TileInput { num_atoms: c.na, num_nbor: c.nn, rij: &c.rij, mask: &c.mask, elems: None };
    let engines: Vec<Box<dyn ForceEngine>> = vec![
        Box::new(BaselineEngine::new(
            params, idx.clone(), c.beta.clone(), Staging::Monolithic,
        )),
        Box::new(FusedEngine::new(
            params, idx.clone(), c.beta.clone(), FusedConfig::default(), "fused",
        )),
        Variant::V5.build(params, idx.clone(), c.beta.clone()),
    ];
    for mut eng in engines {
        let out = eng.compute(&input);
        for (a, (got, want)) in out.ei.iter().zip(c.ei.iter()).enumerate() {
            assert!(
                (got - want).abs() < 1e-8 * (1.0 + want.abs()),
                "{} 2J={} ei[{a}]: {got} vs {want}",
                eng.name(),
                c.twojmax
            );
        }
        let scale = c.dedr.iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for (i, (got, want)) in out.dedr.iter().zip(c.dedr.iter()).enumerate() {
            assert!(
                (got - want).abs() < 1e-8 * scale,
                "{} 2J={} dedr[{i}]: {got} vs {want}",
                eng.name(),
                c.twojmax
            );
        }
    }
}

#[test]
fn golden_case_2j2() {
    let j = require_golden!("case_2j2.json");
    check_case(&parse_case(&j));
}

#[test]
fn golden_case_2j4() {
    let j = require_golden!("case_2j4.json");
    check_case(&parse_case(&j));
}

#[test]
fn golden_case_2j8() {
    let j = require_golden!("case_2j8.json");
    check_case(&parse_case(&j));
}

#[test]
fn golden_case_2j8_sparse() {
    let j = require_golden!("case_2j8_sparse.json");
    check_case(&parse_case(&j));
}

#[test]
fn golden_case_2j14() {
    let j = require_golden!("case_2j14.json");
    check_case(&parse_case(&j));
}
