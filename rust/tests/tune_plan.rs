//! Autotuner integration tests: the full tune → persist → load → serve
//! cycle, and the determinism contract — plan-driven engines are bitwise
//! identical to the serial reference of each bucket's chosen variant
//! (tuning changes speed, never physics).

use repro::snap::coeff::SnapCoeffs;
use repro::snap::engine::TileInput;
use repro::snap::variants::Variant;
use repro::snap::SnapIndex;
use repro::tune::{calibrate, PlanEntry, PlanKey, SearchOptions, ShapeBucket, TunedPlan};
use repro::util::json::Json;
use repro::util::XorShift;
use std::sync::Arc;

fn random_tile(seed: u64, na: usize, nn: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rng = XorShift::new(seed);
    let mut rij = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..na * nn {
        for _ in 0..3 {
            rij.push(rng.uniform(-2.4, 2.4));
        }
        mask.push(if rng.next_f64() > 0.25 { 1.0 } else { 0.0 });
    }
    (rij, mask)
}

/// The acceptance-criterion determinism proof: for every shape bucket, a
/// plan-driven dispatch returns exactly the bytes the chosen variant's
/// plain serial engine returns.
#[test]
fn plan_driven_engines_match_serial_reference_bitwise() {
    let twojmax = 2usize;
    let idx = SnapIndex::new(twojmax);
    let coeffs = SnapCoeffs::synthetic(twojmax, idx.idxb_max, 9);
    let key = PlanKey {
        twojmax,
        threads: repro::util::parallel::num_threads(),
        nelems: 1,
    };
    let mut plan = TunedPlan::default_plan(key);
    plan.set_entry(
        ShapeBucket::Small,
        PlanEntry { variant: Variant::V7, shards: 1, min_atoms_per_shard: 1 },
    );
    plan.set_entry(
        ShapeBucket::Medium,
        PlanEntry { variant: Variant::Fused, shards: 3, min_atoms_per_shard: 4 },
    );
    plan.set_entry(
        ShapeBucket::Large,
        PlanEntry { variant: Variant::FusedAosoa, shards: 4, min_atoms_per_shard: 4 },
    );

    // persist the plan and build through the one construction site
    let path = std::env::temp_dir()
        .join(format!("repro_tune_bitwise_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    repro::tune::cache::save(&path, &plan).unwrap();
    let build = repro::config::EngineSpec::new(twojmax)
        .beta(coeffs.beta.clone())
        .plan(&path)
        .build_factory()
        .unwrap();
    let counters = build.plan.as_ref().unwrap().counters.clone();
    let mut planned = (build.factory)().unwrap();
    std::fs::remove_file(&path).unwrap();

    let params = repro::snap::SnapParams::with_twojmax(twojmax);
    let idx = Arc::new(SnapIndex::new(twojmax));
    let cases = [
        (ShapeBucket::Small, 2usize),
        (ShapeBucket::Medium, 12),
        (ShapeBucket::Large, 80),
    ];
    for (bucket, na) in cases {
        let nn = 5usize;
        let (rij, mask) = random_tile(100 + na as u64, na, nn);
        let tile = TileInput { num_atoms: na, num_nbor: nn, rij: &rij, mask: &mask, elems: None };
        let entry = plan.entry(bucket);
        let mut serial = entry.variant.build(params, idx.clone(), coeffs.beta.clone());
        let want = serial.compute(&tile);
        let got = planned.compute(&tile);
        assert_eq!(want.ei, got.ei, "{bucket:?}: ei diverges from serial {}", serial.name());
        assert_eq!(want.dedr, got.dedr, "{bucket:?}: dedr diverges from serial");
        assert_eq!(counters.dispatches(bucket), 1, "{bucket:?} not routed");
    }
}

/// End-to-end lifecycle: calibrate → persist → reload hits the cache with
/// an identical plan, and the BENCH_tune frontier record is well-formed
/// (valid JSON, every bucket explored, exactly one chosen winner each,
/// chosen points consistent with the plan).
#[test]
fn tune_persist_reload_cycle() {
    let opts = SearchOptions {
        budget_ms: 0,
        warmup: 0,
        reps: 3,
        variant_candidates: vec![Variant::V7, Variant::Fused],
        shard_candidates: vec![1, 2],
        ..SearchOptions::new(2)
    };
    let outcome = calibrate(&opts).unwrap();

    // persist + reload: identical plan, cache hit under the same key
    let path = std::env::temp_dir()
        .join(format!("repro_tune_cycle_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    repro::tune::cache::save(&path, &outcome.plan).unwrap();
    let (loaded, status) = repro::tune::cache::load_or_default(&path, outcome.plan.key);
    assert!(status.is_hit(), "{status:?}");
    assert_eq!(loaded, outcome.plan);
    std::fs::remove_file(&path).unwrap();

    // the frontier record: parseable, complete, consistent
    let text = repro::bench::tune_json(&outcome.plan.key, &outcome.frontier);
    let j = Json::parse(text.trim()).expect("BENCH_tune.json must parse");
    assert_eq!(j.get("bench").and_then(Json::as_str), Some("tune"));
    assert_eq!(j.get("twojmax").and_then(Json::as_usize), Some(2));
    let points = j.get("points").and_then(Json::as_arr).expect("points");
    assert_eq!(points.len(), outcome.frontier.len());
    for bucket in ShapeBucket::ALL {
        let of_bucket: Vec<&Json> = points
            .iter()
            .filter(|p| p.get("bucket").and_then(Json::as_str) == Some(bucket.label()))
            .collect();
        assert!(!of_bucket.is_empty(), "bucket {bucket:?} missing from record");
        let chosen: Vec<&&Json> = of_bucket
            .iter()
            .filter(|p| p.get("chosen") == Some(&Json::Bool(true)))
            .collect();
        assert_eq!(chosen.len(), 1, "bucket {bucket:?}: exactly one winner");
        let e = outcome.plan.entry(bucket);
        assert_eq!(
            chosen[0].get("variant").and_then(Json::as_str),
            Some(e.variant.label()),
            "plan/record winner mismatch for {bucket:?}"
        );
        assert_eq!(chosen[0].get("shards").and_then(Json::as_usize), Some(e.shards));
        assert!(chosen[0].get("p50_ms").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
